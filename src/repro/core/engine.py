"""SnapshotEngine — unified, transparent CPU+device checkpointing.

The CRIUgpu workflow (paper Fig. 4a), adapted to the JAX runtime:

  checkpoint(step):
    init plugins("dump")
    ① PAUSE_DEVICES        lock: drain async dispatch (timeout → abort and
                           leave the job running, paper §3.1.1)
    ② CHECKPOINT_DEVICES   device→host: copy every addressable shard into
                           host memory (replica-0 dedup)
    ③ DUMP_EXT_STATE       host-side state via plugins (data cursor, RNG,
                           metrics — the CRIU memory-dump analogue)
    ④ write + commit       pack files, then MANIFEST.json atomically;
                           sync mode: before resuming (paper-faithful —
                           the app is "frozen" for dump+write);
                           async mode: resume after ②/③, write in a
                           background thread (beyond-paper, CheckFreq-style)
    exit plugins(success)

  restore(step, mesh, shardings):
    read newest valid manifest (CRC-verified, torn images skipped)
    RESTORE_EXT_STATE → UPDATE_TOPOLOGY_MAP → RESUME_DEVICES_LATE
    identical topology → 1:1 shard placement; different → elastic reshard.

Transparency contract: the training/serving code never defines checkpoint
logic.  The runtime attaches a *state provider* (a zero-arg callable
returning the live root pytrees — the "process tree"), and host-side bits
register CallbackPlugins.  Device state is captured from the arrays
themselves (avals + shardings + shard buffers), exactly as the driver owns
GPU state in CRIUgpu.
"""
from __future__ import annotations

import os
import threading
import time
import warnings
from typing import Any, Callable, Dict, List, Optional

import jax

from repro.chaos import hooks as chaos_hooks
from repro.core.dirty import DirtyTracker
from repro.obs import journal as obs_journal
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace
from repro.core.lock import LockTimeout
from repro.core.plugins import (CallbackPlugin, Hook, HookContext, Plugin,
                                PluginRegistry)
from repro.core.snapshot_io import (SnapshotStore, SnapshotWriter,
                                    pack_host_blob)
from repro.core.streams import UnsafeOpInFlight
from repro.core.topology import mesh_fingerprint

PyTree = Any
StateProvider = Callable[[], Dict[str, PyTree]]

_UNSET = object()          # sentinel: legacy kwarg not explicitly passed


class CheckpointAborted(RuntimeError):
    pass


class PendingWriteStalled(TimeoutError):
    """wait_pending(timeout_s=...) found the background writer still
    running past the deadline.  The thread is left joinable: call
    wait_pending() again (with or without a timeout) once the I/O
    recovers, or inspect ``engine.write_error`` after it dies."""

    def __init__(self, step, waited_s: float):
        self.step = step
        self.waited_s = waited_s
        super().__init__(
            f"async snapshot write for step {step} still running after "
            f"{waited_s:.1f}s — the writer thread may be wedged on "
            f"degraded I/O; it remains joinable (retry wait_pending() "
            f"or check write_error)")


class SnapshotEngine:
    """Checkpoint/restore mechanism.

    Canonical construction is ``SnapshotEngine(run_dir, options=opts)``
    where `opts` is a :class:`repro.api.CheckpointOptions`; most callers
    should go one level higher and use :class:`repro.api.CheckpointSession`.
    The historical per-knob keyword form still works but is a deprecated
    shim over the options object.
    """

    def __init__(self, run_dir: str,
                 plugins: Optional[List[Plugin]] = None,
                 mode=_UNSET,                        # "sync" | "async"
                 incremental=_UNSET,
                 compress=_UNSET,
                 keep=_UNSET,                        # 0 = keep all
                 lock_timeout_s=_UNSET,
                 replicator=None,                    # core.replication peer
                 restore_threads=_UNSET,             # parallel entry loads
                 mesh=None,
                 options=None,                       # api.CheckpointOptions
                 backend=None):                      # name | Plugin instance
        from repro.api.options import CheckpointOptions
        legacy = {k: v for k, v in dict(
            mode=mode, incremental=incremental, compress=compress,
            keep=keep, lock_timeout_s=lock_timeout_s,
            restore_threads=restore_threads).items() if v is not _UNSET}
        if legacy:
            if options is not None:
                raise TypeError(
                    "pass either options=CheckpointOptions(...) or legacy "
                    f"keyword(s) {sorted(legacy)}, not both")
            warnings.warn(
                "SnapshotEngine(mode=..., incremental=..., ...) keyword "
                "soup is deprecated; pass "
                "options=repro.api.CheckpointOptions(...) or use "
                "repro.api.CheckpointSession",
                DeprecationWarning, stacklevel=2)
            options = CheckpointOptions(**legacy)
        self.options = options if options is not None else CheckpointOptions()
        self.options.validate()

        self.run_dir = run_dir
        os.makedirs(run_dir, exist_ok=True)
        self.store = SnapshotStore(run_dir)
        self.device_plugin = self._make_backend(backend)
        self.registry = PluginRegistry([self.device_plugin]
                                       + list(plugins or []))
        self.mode = self.options.mode
        self.incremental = self.options.incremental
        self.compress = self.options.compress
        self.keep = self.options.keep
        self.replicator = replicator
        if replicator is None and self.options.replicate_to:
            policy = self.options.transfer_policy
            if policy is not None and policy.mode == "delta":
                from repro.transfer import DeltaReplicator
                self.replicator = DeltaReplicator(
                    self.options.replicate_to, workers=policy.workers)
            else:
                from repro.core.replication import DirReplicator
                self.replicator = DirReplicator(self.options.replicate_to)
        self.mesh = mesh
        if self.options.capture == "concurrent":
            from repro.api.options import OptionsError
            feats = getattr(self.device_plugin, "features", frozenset())
            if "dirty_tracking" not in feats:
                raise OptionsError(
                    f"capture='concurrent' needs a backend with the "
                    f"'dirty_tracking' feature; backend "
                    f"{getattr(self.device_plugin, 'backend_name', self.device_plugin.name)!r} "
                    f"offers {sorted(feats)} (sync-only capture)")
        self._concurrent: Optional["ConcurrentCapture"] = None
        self._provider: Optional[StateProvider] = None
        self._pending: Optional[threading.Thread] = None
        self._pending_ctx: Optional[HookContext] = None
        self._pending_err: List[BaseException] = []
        self._write_error: Optional[str] = None
        # lazy-restore stream state: at most one background materializer
        # per engine; a failed stream quarantines its step so the retry's
        # newest-valid scan falls back past it (eager semantics)
        self._lazy = None
        self._lazy_ctx = None
        self._lazy_step: Optional[int] = None
        self._last_restored: Optional[Dict[str, Any]] = None
        self._quarantined: set = set()
        self.last_stats: Dict[str, Any] = {}
        # step of the newest image committed by THIS engine instance —
        # lets callers distinguish "an image of step N exists" from "WE
        # dumped step N" (a leftover from a previous incarnation may
        # carry a different trajectory)
        self.last_commit_step: Optional[int] = None

    def _make_backend(self, backend) -> Plugin:
        from repro.core.backends import create_backend
        if backend is None:
            backend = "jax"
        if isinstance(backend, str):
            return create_backend(
                backend, lock_timeout_s=self.options.lock_timeout_s,
                restore_threads=self.options.restore_threads)
        return backend                     # pre-built DeviceBackend plugin

    # ------------------------------------------------------------ wiring
    def attach(self, provider: StateProvider) -> None:
        """Attach the live state roots (the 'process tree')."""
        self._provider = provider

    def register_host_state(self, name: str, getter: Callable[[], Any],
                            setter: Callable[[Any], None]) -> None:
        self.registry.add(CallbackPlugin(name, getter, setter))

    def add_plugin(self, plugin: Plugin) -> None:
        self.registry.add(plugin)

    # ------------------------------------------------------------ dump
    def checkpoint(self, step: int) -> str:
        """Create a unified snapshot.  Returns the snapshot directory.

        With ``options.capture == "concurrent"`` this still blocks until
        the image commits, but runs the soft-freeze protocol (pin →
        speculate → validate → patch → commit); callers that want the
        overlap use :meth:`begin_concurrent` and step between ``begin``
        and ``finalize``."""
        return self.snapshot_while_running(step)

    def snapshot_while_running(self, step: int) -> str:
        """Commit a snapshot of `step` while minimizing the pause the job
        observes — the capture primitive behind each pre-copy migration
        round (and the body of :meth:`checkpoint`, which shares it).

        With ``capture="concurrent"`` this is the soft-freeze protocol
        (the job is only paused for the pin + validate windows, and the
        bulk speculation overlaps its next steps); otherwise it degrades
        to an ordinary stop-the-world dump — correctness is identical,
        only the pause differs.  Returns the snapshot directory either
        way, so migration code can push the image without caring which
        capture path ran.
        """
        if self.options.capture == "concurrent":
            handle = self.begin_concurrent(step)
            handle.wait_speculated()
            return handle.finalize()
        return self.commit_dump(self.freeze(step))

    def freeze(self, step: int) -> HookContext:
        """Phases ①–③: quiesce devices and capture device+host state.

        On return the image exists *in host memory* and the job is frozen
        (device lock held).  Finish with :meth:`commit_dump` (write +
        manifest + resume) or :meth:`abort_dump` (resume, no image) — the
        session's ``frozen()`` context manager wraps exactly this pair.
        """
        if self._provider is None:
            raise RuntimeError("no state provider attached")
        if self._concurrent is not None:
            # settle any in-flight soft-freeze capture first: a second
            # dump must never interleave with an open stripe set
            self._concurrent.finalize()
        self.wait_pending()
        if self._lazy is not None:
            # a dump must never freeze a half-restored job: join the
            # background stream first (raises if it died — the caller's
            # state is incomplete and must not be captured as an image)
            self.restore_barrier()

        ctx = HookContext("dump", step)
        ctx.roots = self._provider()
        self.registry.init_all("dump")
        ctx.stats["t_start"] = time.perf_counter()
        try:
            with obs_trace.span("dump.pause", step=step):
                self.registry.run(Hook.PAUSE_DEVICES, ctx)   # ① lock
            t_frozen = time.perf_counter()
            with obs_trace.span("dump.capture", step=step):
                self.registry.run(Hook.CHECKPOINT_DEVICES, ctx)  # ② dev→host
            with obs_trace.span("dump.ext_state", step=step):
                self.registry.run(Hook.DUMP_EXT_STATE, ctx)  # ③ host state
            ctx.stats["frozen_s"] = time.perf_counter() - t_frozen
        except LockTimeout as e:
            # abort-to-running: nothing was mutated; plugins may roll back
            self.registry.exit_all("dump", False)
            raise CheckpointAborted(str(e)) from e
        except UnsafeOpInFlight as e:
            # abort-to-running: async work could not be quiesced at the
            # capture boundary — resume rather than snapshot torn state
            self.device_plugin.lock.unlock()
            self.registry.exit_all("dump", False)
            raise CheckpointAborted(str(e)) from e
        except Exception:
            self.device_plugin.lock.unlock()
            self.registry.exit_all("dump", False)
            raise
        return ctx

    def abort_dump(self, ctx: HookContext) -> None:
        """Abandon a frozen dump: resume the job, write nothing."""
        self.device_plugin.lock.unlock()
        self.registry.exit_all("dump", False)

    def commit_dump(self, ctx: HookContext) -> str:
        """Phase ④: write + commit the frozen capture, resume the job."""
        t_start = ctx.stats.pop("t_start", time.perf_counter())
        if self.mode == "sync":
            try:
                path = self._write(ctx)                       # ④ write+commit
            except Exception:
                self.registry.exit_all("dump", False)
                raise
            ctx.stats["total_s"] = time.perf_counter() - t_start
            self.device_plugin.lock.unlock()                  # resume
            self.registry.exit_all("dump", True)
            self.last_stats = dict(ctx.stats)
            self._write_error = None               # last dump is clean
            self.last_commit_step = ctx.step
            return path

        # async: resume immediately, write in background (CheckFreq-style)
        self.device_plugin.lock.unlock()
        ctx.stats["locked_total_s"] = time.perf_counter() - t_start
        path = self._snapshot_path(ctx.step)

        # the writer thread has its own span context: hand it the
        # caller's (job attribution survives the async handoff)
        obs_ctx = obs_trace.current_context()

        def writer():
            with obs_trace.context(**obs_ctx):
                try:
                    self._write(ctx)
                    self._write_error = None       # last dump is clean
                    self.last_commit_step = ctx.step
                    self.registry.exit_all("dump", True)
                except BaseException as e:
                    self._pending_err.append(e)
                    # surface immediately: a silently-failed async dump
                    # must not look like a committed image to anyone
                    # polling stats
                    self._write_error = repr(e)
                    self.last_stats["write_error"] = repr(e)
                    self.registry.exit_all("dump", False)

        # publish the stats snapshot BEFORE the writer starts: the thread
        # keeps mutating ctx.stats (and on failure writes write_error into
        # self.last_stats), so copying after start would race both ways
        self.last_stats = dict(ctx.stats)
        self._pending = threading.Thread(target=writer, daemon=True,
                                         name="repro-async-writer")
        self._pending_ctx = ctx
        self._pending.start()
        return path

    def _snapshot_path(self, step: int) -> str:
        from repro.core.snapshot_io import snapshot_dir
        return snapshot_dir(self.run_dir, step)

    # ----------------------------------------------- concurrent capture
    def begin_concurrent(self, step: int) -> "ConcurrentCapture":
        """Start a soft-freeze capture (PhoenixOS-style validated
        speculation).

        Pin pause: quiesce the capture boundary (device lock + stream
        drain), pin the state tree (strong refs + identities) and start
        dirty tracking, then *resume the job*.  A background thread
        speculatively captures the pinned shards into an open stripe set
        while the step loop keeps running.  ``handle.finalize()`` takes
        the short validate pause: drain again, re-hash dirtied entries
        against the speculated per-chunk content hashes, re-capture only
        the invalidated ones, and commit — the committed image is the
        state at the *validate* pause, bit-exact vs a sync dump taken
        there.  Raises :class:`CheckpointAborted` (job keeps running, no
        image) on lock timeout or an unsafe op in flight.
        """
        if self._provider is None:
            raise RuntimeError("no state provider attached")
        if self.options.capture != "concurrent":
            from repro.api.options import OptionsError
            raise OptionsError(
                "begin_concurrent() requires "
                "CheckpointOptions(capture='concurrent'); "
                f"these options say capture={self.options.capture!r}")
        if self._concurrent is not None:
            self._concurrent.finalize()          # settle the previous one
        self.wait_pending()
        if self._lazy is not None:
            self.restore_barrier()

        ctx = HookContext("dump", step)
        ctx.roots = self._provider()
        self.registry.init_all("dump")
        ctx.stats["t_begin"] = time.perf_counter()
        try:
            with obs_trace.span("dump.pause", step=step, phase="pin"):
                self.registry.run(Hook.PAUSE_DEVICES, ctx)  # pin pause
        except LockTimeout as e:
            self.registry.exit_all("dump", False)
            raise CheckpointAborted(str(e)) from e
        except UnsafeOpInFlight as e:
            self.device_plugin.lock.unlock()
            self.registry.exit_all("dump", False)
            raise CheckpointAborted(str(e)) from e
        except Exception:
            self.device_plugin.lock.unlock()
            self.registry.exit_all("dump", False)
            raise
        try:
            tracker = DirtyTracker()
            pinned = self.device_plugin.flatten_keys(ctx.roots)
            tracker.pin(pinned)
            self.device_plugin.begin_tracking(tracker)
            writer = self._make_writer(step)
        except Exception:
            self.device_plugin.end_tracking()
            self.device_plugin.lock.unlock()
            self.registry.exit_all("dump", False)
            raise
        handle = ConcurrentCapture(self, ctx, writer, pinned, tracker)
        self.device_plugin.lock.unlock()                   # job resumes
        ctx.stats["pin_pause_s"] = (time.perf_counter()
                                    - ctx.stats["t_begin"])
        ctx.stats["pin_lock_s"] = ctx.stats.pop("lock_s", 0.0)
        self._concurrent = handle
        handle._start()
        return handle

    @property
    def concurrent_capture(self) -> Optional["ConcurrentCapture"]:
        """The in-flight soft-freeze capture handle, if any."""
        return self._concurrent

    def _make_writer(self, step: int) -> SnapshotWriter:
        opts = self.options
        prev_manifest = None
        if self.incremental:
            # parent = newest step strictly below the one being dumped: a
            # re-dump of an existing step (checkpoint-on-signal right
            # after a periodic dump of the same step) must never use the
            # image it is about to overwrite as its own parent — the
            # locations would point at a pack the commit just replaced
            prev_steps = [s for s in self.store.list_steps()
                          if s < step]
            if prev_steps:
                prev_manifest = self.store.manifest(prev_steps[-1])
        return SnapshotWriter(self.run_dir, step,
                              host_id=jax.process_index(),
                              compress=self.compress,
                              prev_manifest=prev_manifest,
                              pack_format=opts.pack_format,
                              chunk_bytes=opts.chunk_mb << 20,
                              stripes=opts.stripes,
                              io_threads=opts.effective_io_threads())

    def _writer_stats(self, ctx: HookContext, writer: SnapshotWriter) -> None:
        ctx.stats["written_bytes"] = float(writer.written_bytes)
        ctx.stats["reused_bytes"] = float(writer.reused_bytes)
        # pipeline stage timings (thread-time, so compress_s + io_s
        # can legitimately exceed write_s when stages overlap)
        ctx.stats["compress_s"] = writer.compress_s
        ctx.stats["io_s"] = writer.io_s
        stripe_bytes = writer.stripe_bytes
        if stripe_bytes and max(stripe_bytes) > 0:
            ctx.stats["stripe_utilization"] = (
                min(stripe_bytes) / max(stripe_bytes))

    def _write(self, ctx: HookContext) -> str:
        t0 = time.perf_counter()
        writer = self._make_writer(ctx.step)
        try:
            with obs_trace.span("dump.write", step=ctx.step,
                                mode=self.mode):
                writer.write_states(ctx.device_snapshot)
                writer.write_host_state(ctx.host_state)
                t_serialize = time.perf_counter() - t0
                ctx.stats["host_bytes"] = float(
                    len(pack_host_blob(ctx.host_state)))
                path = writer.commit(topology=mesh_fingerprint(self.mesh),
                                     stats=ctx.stats,
                                     extra={"warnings": ctx.warnings,
                                            "mode": self.mode,
                                            "capture": "sync",
                                            "incremental": self.incremental})
            # commit() drains the pipeline and fsyncs; only now are the
            # stage timings and reuse accounting final (so these live in
            # last_stats, not in the manifest's embedded stats)
            ctx.stats["write_s"] = time.perf_counter() - t0
            ctx.stats["serialize_s"] = t_serialize
            self._writer_stats(ctx, writer)
        except Exception:
            writer.abort()
            raise
        self._after_commit(ctx, path)
        return path

    def _after_commit(self, ctx: HookContext, path: str) -> str:
        if self.replicator is not None:
            with obs_trace.span("dump.replicate", step=ctx.step):
                t_rep = time.perf_counter()
                self.replicator.push(self.run_dir, ctx.step)
                ctx.stats["replicate_s"] = time.perf_counter() - t_rep
            # replication counters (files/bytes copied vs skipped for the
            # dir replicator, chunks/bytes sent vs reused for the delta
            # one) ride along in the dump stats under a replica_ prefix
            # and mirror into the metrics registry; a replicator without
            # last_stats used to drop them invisibly — warn once instead
            obs_metrics.counter_add("replica.push_count")
            # the Replicator protocol's `stats` property; fall back to the
            # legacy `last_stats` attribute for third-party replicators
            rep_stats = getattr(self.replicator, "stats", None)
            if not isinstance(rep_stats, dict):
                rep_stats = getattr(self.replicator, "last_stats", None)
            if rep_stats is None:
                obs_metrics.counter_add("replica.missing_stats")
                obs_metrics.warn_once(
                    f"replicator-no-stats:{type(self.replicator).__name__}",
                    f"replicator {type(self.replicator).__name__} exposes "
                    f"no last_stats; replication counters for step "
                    f"{ctx.step} (and later dumps) are not recorded")
                rep_stats = {}
            for k, v in rep_stats.items():
                if isinstance(v, (int, float)):
                    ctx.stats[f"replica_{k}"] = v
                    obs_metrics.counter_add(f"replica.{k}", v)
        obs_metrics.counter_add("dump.count")
        obs_metrics.counter_add("dump.bytes_written",
                                ctx.stats.get("written_bytes", 0.0))
        obs_metrics.counter_add("dump.bytes_deduped",
                                ctx.stats.get("reused_bytes", 0.0))
        if "frozen_s" in ctx.stats:
            obs_metrics.observe("dump.frozen_s", ctx.stats["frozen_s"])
        obs_journal.emit("dump", "commit", step=ctx.step,
                         bytes=ctx.stats.get("written_bytes"),
                         frozen_s=ctx.stats.get("frozen_s"))
        if chaos_hooks.INJECTOR is not None:
            # chaos: lost-writeback site — the image is committed (and
            # replicated), so an injected local corruption here models a
            # dropped fsync that only the next restore can observe
            chaos_hooks.fire("engine.dump_done", run_dir=self.run_dir,
                             step=ctx.step, path=path)
        if self.keep:
            self.store.gc(self.keep)
        return path

    def wait_pending(self, timeout_s: Optional[float] = None) -> None:
        """Join the async background writer.

        ``timeout_s=None`` blocks until it finishes (historical
        behaviour).  With a timeout, a writer still running past the
        deadline raises :class:`PendingWriteStalled` instead of hanging
        forever (chaos ``degraded_io`` can wedge a writer indefinitely);
        the thread stays joinable so a later call can still reap it."""
        if self._pending is not None:
            t0 = time.perf_counter()
            step = (self._pending_ctx.step
                    if self._pending_ctx is not None else None)
            with obs_trace.span("dump.wait_pending", step=step) as sp:
                self._pending.join(timeout_s)
                if self._pending.is_alive():
                    waited = time.perf_counter() - t0
                    # the stall must be visible in the journal, not only
                    # as the raised exception
                    sp.set(stalled=True, waited_s=waited)
                    obs_metrics.observe("dump.pending_stall_s", waited)
                    obs_journal.emit("dump", "pending_stall", step=step,
                                     waited_s=waited, timeout_s=timeout_s)
                    raise PendingWriteStalled(step, waited)
            self._pending = None
            ctx, self._pending_ctx = self._pending_ctx, None
            if ctx is not None and not self._pending_err:
                # fold the background writer's stage timings (write_s,
                # written_bytes, compress_s, io_s, ...) into last_stats
                # now that the thread is joined — async dumps otherwise
                # never publish their write-stage stats
                self.last_stats.update(ctx.stats)
        if self._pending_err:
            # drain *every* queued failure, not just the newest: an older
            # failed dump must never be masked by a newer successful one
            errs = list(self._pending_err)
            self._pending_err.clear()
            msg = "; ".join(repr(e) for e in errs)
            self._write_error = msg
            self.last_stats["write_error"] = msg
            if len(errs) > 1:
                raise RuntimeError(
                    f"{len(errs)} async snapshot writes failed: {msg}"
                ) from errs[0]
            raise errs[0]

    @property
    def write_error(self) -> Optional[str]:
        """repr of the most recent async write failure (None if the last
        background dump committed cleanly)."""
        return self._write_error

    # ------------------------------------------------------------ restore
    def _verify_reader(self, reader, lazy: bool) -> None:
        """Pre-restore image check: eager verifies every entry; lazy
        verifies the critical set (plus the blobs read eagerly) so the
        job can resume before the cold entries are even read — those keep
        the same corruption guarantee because every background chunk read
        re-checks its stored CRC."""
        if lazy:
            from repro.core.lazy import critical_pack_names, split_schedule
            critical, _ = split_schedule(reader,
                                         self.options.critical_states)
            reader.verify_entries(critical_pack_names(reader, critical))
        else:
            reader.verify_all()

    def _make_healer(self, step: int):
        """Background-stream heal hook: re-pull the image (and its delta
        chain) from the replica, so a torn background chunk is repaired
        in place instead of killing the stream."""
        rep = self.replicator
        if rep is None or not hasattr(rep, "pull"):
            return None

        def heal(state: str, path: str, exc: BaseException) -> bool:
            try:
                manifest = self.store.manifest(step)
                steps = sorted(self.store.referenced_steps(manifest)
                               | {step})
            except Exception:
                steps = [step]
            healed = False
            for s in steps:
                try:
                    if rep.pull(self.run_dir, s) is not None:
                        healed = True
                except Exception:
                    continue
            return healed

        return heal

    def _abandon_lazy(self) -> None:
        """A newer restore supersedes any still-streaming one: cancel it
        and wait for the thread to stop (its reader is closed and its pin
        released by the stream's own cleanup).  Errors are not raised —
        the superseding restore is frequently the retry path."""
        mat, self._lazy = self._lazy, None
        self._lazy_ctx, self._lazy_step = None, None
        if mat is not None and not mat.done:
            mat.cancel()
            mat.wait_done(timeout=60.0)

    def restore(self, step: Optional[int] = None, mesh=None,
                shardings: Optional[Dict[str, Any]] = None,
                verify: Optional[bool] = None,
                wait: Optional[str] = None) -> Dict[str, Any]:
        """Unified restore.  Returns {state_name: nested-dict pytree}; host
        state is pushed back through the registered CallbackPlugins.

        With ``options.restore_mode == "lazy"`` (or ``wait="critical"``)
        the call returns as soon as the critical set is placed; the
        remaining entries stream in the background and
        :meth:`restore_barrier` joins them.  ``wait="all"`` forces a full
        materialization before returning (eager restores always behave
        this way)."""
        if verify is None:
            verify = self.options.verify_restore
        if wait not in (None, "critical", "all"):
            raise ValueError(f"wait must be 'critical' or 'all', "
                             f"got {wait!r}")
        # wait="critical" opts a single call into the lazy machinery even
        # under eager options (per-call resume-before-read)
        lazy = self.options.restore_mode == "lazy" or wait == "critical"
        if wait is None:
            wait = "critical" if lazy else "all"
        self.wait_pending()
        self._abandon_lazy()
        t_restore0 = time.perf_counter()
        io_threads = self.options.effective_io_threads()
        # Hold the store lock for the whole critical phase so a gc running
        # in another thread of THIS process (sharing this SnapshotStore,
        # e.g. a concurrent checkpoint with keep=N) cannot delete a step
        # or a delta-chain parent pack out from under the scan/reads.
        # The lazy background stream runs *outside* the lock — it pins its
        # step instead, so gc skips it without blocking behind a
        # deliberately long-running restore.  A gc from a different
        # process (or a second store instance on the run_dir) is not
        # serialized by this lock — the newest-valid scan tolerates
        # vanishing images by falling back, but an explicitly requested
        # step may still fail mid-read there.
        sp_crit = obs_trace.span("restore.critical",
                                 mode="lazy" if lazy else "eager")
        with sp_crit, self.store.lock:
            steps = self.store.list_steps()
            if step is None:
                # newest *valid* image: fall back past torn/corrupt images
                # and past steps whose lazy background stream died (the
                # quarantine — a retry must not pick the same bad image)
                for s in reversed(steps):
                    if s in self._quarantined:
                        continue
                    reader = None
                    try:
                        reader = self.store.reader(s, verify=verify,
                                                   io_threads=io_threads)
                        if verify:
                            self._verify_reader(reader, lazy)
                        step = s
                        break
                    except Exception:
                        if reader is not None:
                            reader.close()
                        continue
                else:
                    if self.replicator is not None:
                        got = self.replicator.pull_latest(self.run_dir)
                        if got is not None:
                            self._quarantined.discard(got)
                            out = self.restore(step=got, mesh=mesh,
                                               shardings=shardings,
                                               verify=verify, wait=wait)
                            self.last_stats["restored_from_replica"] = True
                            return out
                    raise FileNotFoundError(
                        f"no restorable snapshot under {self.run_dir}")
            else:
                # explicitly requested step: verify with the same rigor as
                # the newest-valid scan — a torn image must raise, not
                # restore garbage (historically this path skipped
                # verify_all()).
                reader = self.store.reader(step, verify=verify,
                                           io_threads=io_threads)
                if verify:
                    try:
                        self._verify_reader(reader, lazy)
                    except Exception:
                        reader.close()
                        raise

            sp_crit.set(step=step)
            ctx = HookContext("restore", step)
            ctx.reader = reader
            ctx.manifest = reader.manifest
            ctx.target_mesh = mesh if mesh is not None else self.mesh
            ctx.target_shardings = shardings or {}
            ctx.restore_threads = self.options.restore_threads or io_threads
            ctx.lazy = lazy
            if lazy:
                ctx.critical_specs = self.options.critical_states
                self.store.pin(step)
                ctx.lazy_reopen = (
                    lambda s=step: self.store.reader(
                        s, verify=verify, io_threads=io_threads))
                ctx.lazy_heal = self._make_healer(step)
                ctx.lazy_on_done = (lambda s=step: self.store.unpin(s))
            self.registry.init_all("restore")
            materializer = None
            try:
                ctx.host_state = reader.host_state()
                self.registry.run(Hook.RESTORE_EXT_STATE, ctx)
                self.registry.run(Hook.UPDATE_TOPOLOGY_MAP, ctx)
                self.registry.run(Hook.RESUME_DEVICES_LATE, ctx)
                materializer = getattr(ctx, "materializer", None)
            except Exception:
                self.registry.exit_all("restore", False)
                ctx.stats.update(reader.io_stats())
                reader.close()
                if lazy:
                    self.store.unpin(step)
                raise
            ctx.stats.update(reader.io_stats())   # read_s, decompress_s
            if materializer is None:
                reader.close()                    # eager: image fully read
                if lazy:
                    self.store.unpin(step)        # backend without lazy
        self.registry.exit_all("restore", True)
        if lazy:
            ctx.stats["restore_critical_s"] = (time.perf_counter()
                                               - t_restore0)
        ctx.stats["restore_mode"] = "lazy" if lazy else "eager"
        obs_metrics.counter_add("restore.count")
        if lazy:
            obs_metrics.observe("restore.critical_s",
                                ctx.stats["restore_critical_s"])
        obs_journal.emit("restore", "resumed", step=step,
                         mode=ctx.stats["restore_mode"])
        self.last_stats = dict(ctx.stats)
        self.last_stats["topology_mode"] = ctx.topology_map.get("mode")
        self._last_restored = ctx.restored
        if materializer is not None:
            self._lazy = materializer
            self._lazy_ctx = ctx
            self._lazy_step = step
            materializer.start()                  # stream the cold tail
            if wait == "all":
                return self.restore_barrier()
        return ctx.restored

    def restore_barrier(self) -> Optional[Dict[str, Any]]:
        """Join the background restore stream.

        Blocks until every lazily-scheduled entry has landed, then
        returns the complete restored tree.  If the stream died (torn
        chunk that could not be healed, vanished pack), raises
        :class:`repro.core.lazy.LazyRestoreError`, quarantines the step,
        and a retried :meth:`restore` falls back to an eager restore of
        the previous committed image.  A no-op after eager restores."""
        mat = self._lazy
        if mat is None:
            return self._last_restored
        try:
            mat.join()
        except BaseException:
            if self._lazy_step is not None:
                self._quarantined.add(self._lazy_step)
            self._lazy, self._lazy_ctx, self._lazy_step = None, None, None
            raise
        for k in ("background_s", "background_bytes",
                  "background_entries", "healed_entries"):
            self.last_stats[k] = mat.stats.get(k, 0.0)
        self.last_stats["restore_background_s"] = mat.stats["background_s"]
        restored = self._lazy_ctx.restored
        self._last_restored = restored
        self._lazy, self._lazy_ctx, self._lazy_step = None, None, None
        return restored

    @property
    def lazy_pending(self) -> bool:
        """True while a background restore stream is still outstanding."""
        return self._lazy is not None

    @staticmethod
    def retree(template: PyTree, raw_tree: Any) -> PyTree:
        """Rebuild `template`'s pytree types (e.g. OptState dataclasses)
        from a raw nested-dict restore view."""
        from repro.core.device_plugin import flatten_with_paths
        flat = flatten_with_paths(template)
        raw = flatten_with_paths(raw_tree)
        missing = set(flat) - set(raw)
        if missing:
            raise KeyError(f"snapshot missing leaves: {sorted(missing)[:5]}")
        _, treedef = jax.tree_util.tree_flatten(template)
        return jax.tree_util.tree_unflatten(
            treedef, [raw[k] for k in flat])

    def restore_into(self, template: PyTree, state: str = "train_state",
                     step: Optional[int] = None, mesh=None,
                     shardings: Optional[PyTree] = None,
                     wait: Optional[str] = None) -> PyTree:
        """Restore one state into the caller's pytree structure (types
        preserved — e.g. OptState dataclasses).

        In lazy mode the typed reassembly needs every template leaf, so
        if the background stream has not yet landed them all this joins
        it (`restore_barrier`) before rebuilding — callers that want the
        resume-before-read overlap should use :meth:`restore` with
        ``wait="critical"`` and :meth:`retree` the cold subtrees after
        the barrier (see ``runtime.Trainer.restore``)."""
        restored = self.restore(step=step, mesh=mesh,
                                shardings={state: shardings}
                                if shardings is not None else None,
                                wait=wait)
        if self._lazy is not None:
            # always join the stream: even if every template leaf already
            # landed, leaving the materializer outstanding would hand the
            # caller a "complete" tree with lazy_pending still True
            restored = self.restore_barrier()
        return self.retree(template, restored[state])

    def latest_step(self) -> Optional[int]:
        return self.store.latest_step()


class ConcurrentCapture:
    """Handle for one in-flight soft-freeze capture.

    Lifecycle: ``engine.begin_concurrent(step)`` returns this with the
    speculation thread running and the job resumed; the caller steps
    freely (polling :attr:`speculation_done`), then calls
    :meth:`finalize` for the validate/patch pause and the atomic commit,
    or :meth:`abort` to discard everything.  The committed image is
    bit-exact with the live state at the validate pause — speculation
    that survived validation was, by the content hashes, already
    identical to it.
    """

    def __init__(self, engine: SnapshotEngine, ctx: HookContext,
                 writer: SnapshotWriter, pinned: Dict[str, Any],
                 tracker: DirtyTracker):
        self._engine = engine
        self.ctx = ctx
        self._writer = writer
        self._pinned = pinned
        self._tracker = tracker
        self._stop = threading.Event()
        self._spec_done = threading.Event()
        self._spec_err: Optional[BaseException] = None
        self._speculated: set = set()
        self._done = False
        self._obs_ctx = obs_trace.current_context()
        self._thread = threading.Thread(target=self._speculate,
                                        name="repro-spec-capture",
                                        daemon=True)

    def _start(self) -> None:
        self._thread.start()

    # ------------------------------------------------------------- state
    @property
    def step(self) -> int:
        return self.ctx.step

    @property
    def stats(self) -> Dict[str, Any]:
        return self.ctx.stats

    @property
    def speculation_done(self) -> bool:
        """True once the background pass over the pinned tree finished
        (finalize() after this point pays the smallest pause)."""
        return self._spec_done.is_set()

    def wait_speculated(self, timeout: Optional[float] = None) -> bool:
        return self._spec_done.wait(timeout)

    # -------------------------------------------------------- speculation
    def _speculate(self) -> None:
        backend = self._engine.device_plugin
        t0 = time.perf_counter()
        with obs_trace.context(**self._obs_ctx), \
                obs_trace.span("dump.speculate", step=self.ctx.step) as sp:
            try:
                for key, leaf in self._pinned.items():
                    if self._stop.is_set():
                        break
                    if chaos_hooks.INJECTOR is not None:
                        # chaos: mutation-storm site — a handler may mutate
                        # the live leaf mid-speculation (it must call note())
                        chaos_hooks.fire("engine.speculate", key=key,
                                         leaf=leaf, note=self._tracker.note,
                                         step=self.ctx.step,
                                         run_dir=self._engine.run_dir)
                    state, path = key.split("::", 1)
                    try:
                        entry = backend.capture_entry(leaf)
                    except Exception:
                        # donated away / deleted under us: the live value is
                        # captured at the validate pause instead
                        self._tracker.note(key)
                        continue
                    self._writer.put_state_entry(state, path, entry)
                    self._speculated.add(key)
                if not self._stop.is_set():
                    # drain the pack pipeline while the job still runs: once
                    # speculation_done is set, finalize()'s own flush is a
                    # no-op and the validate pause shrinks to hash + commit
                    self._writer.flush()
            except BaseException as e:
                self._spec_err = e
            finally:
                self.ctx.stats["speculate_s"] = time.perf_counter() - t0
                self.ctx.stats["speculated_entries"] = len(self._speculated)
                sp.set(entries=len(self._speculated))
                self._spec_done.set()

    # ----------------------------------------------------------- finalize
    def finalize(self) -> str:
        """Validate pause: quiesce, re-hash dirtied entries against the
        speculated chunk hashes, re-capture only actual mismatches, dump
        host state, commit atomically, resume.  Returns the snapshot
        directory.  Raises CheckpointAborted (no image, job running) on
        lock timeout / unsafe op in flight."""
        if self._done:
            raise RuntimeError("concurrent capture already finalized")
        eng = self._engine
        ctx = self.ctx
        backend = eng.device_plugin
        t_val = time.perf_counter()
        try:
            ctx.roots = eng._provider()
            with obs_trace.span("dump.pause", step=ctx.step,
                                phase="validate"):
                eng.registry.run(Hook.PAUSE_DEVICES, ctx)  # validate pause
        except LockTimeout as e:
            self._cleanup(unlock=False)
            raise CheckpointAborted(str(e)) from e
        except UnsafeOpInFlight as e:
            self._cleanup(unlock=True)
            raise CheckpointAborted(str(e)) from e
        except Exception:
            self._cleanup(unlock=True)
            raise
        try:
            with obs_trace.span("dump.validate", step=ctx.step) as sp_val:
                self._stop.set()
                self._thread.join()
                if self._spec_err is not None:
                    raise self._spec_err
                self._writer.flush()    # speculated chunk records final
                # the post-lock tree is the commit point
                ctx.roots = eng._provider()
                live = backend.flatten_keys(ctx.roots)
                if chaos_hooks.INJECTOR is not None:
                    # chaos: validate site — burst handlers restore their
                    # mutations here so the job's own trajectory is intact
                    chaos_hooks.fire("engine.validate", step=ctx.step,
                                     run_dir=eng.run_dir)
                dirty = self._tracker.dirty_keys(live)
                sp_val.set(dirty=len(dirty))
            recaptured = recaptured_bytes = 0
            with obs_trace.span("dump.patch", step=ctx.step) as sp_patch:
                for key, leaf in live.items():
                    state, path = key.split("::", 1)
                    is_array = (hasattr(leaf, "shape")
                                and hasattr(leaf, "dtype"))
                    if (key in dirty or key not in self._speculated
                            or not is_array):
                        nb = self._writer.reput_state_entry(
                            state, path, backend.capture_entry(leaf))
                        if nb:
                            recaptured += 1
                            recaptured_bytes += nb
                for key in self._pinned:
                    if key not in live:  # structural drift: entry gone
                        state, path = key.split("::", 1)
                        self._writer.drop_state_entry(state, path)
                sp_patch.set(recaptured=recaptured)
            eng.registry.run(Hook.DUMP_EXT_STATE, ctx)
            self._writer.write_host_state(ctx.host_state)
            ctx.stats["host_bytes"] = float(
                len(pack_host_blob(ctx.host_state)))
            ctx.stats["dirty_entries"] = len(dirty)
            ctx.stats["recaptured_entries"] = recaptured
            ctx.stats["recaptured_bytes"] = float(recaptured_bytes)
            ctx.stats["superseded_bytes"] = float(
                self._writer.superseded_bytes)
            ctx.stats["validate_pause_s"] = time.perf_counter() - t_val
            ctx.stats["frozen_s"] = (ctx.stats["pin_pause_s"]
                                     + ctx.stats["validate_pause_s"])
            path = self._writer.commit(
                topology=mesh_fingerprint(eng.mesh), stats=ctx.stats,
                extra={"warnings": ctx.warnings,
                       "mode": eng.mode,
                       "incremental": eng.incremental,
                       "capture": "concurrent",
                       "capture_stats": {
                           k: ctx.stats[k] for k in (
                               "pin_pause_s", "validate_pause_s",
                               "frozen_s", "speculate_s",
                               "speculated_entries", "dirty_entries",
                               "recaptured_entries", "recaptured_bytes",
                               "superseded_bytes")
                           if k in ctx.stats}})
            self._writer_post_commit_stats(ctx)
        except Exception:
            self._cleanup(unlock=True)
            raise
        # the fsync/rename is part of the pause the caller observed
        ctx.stats["validate_pause_s"] = time.perf_counter() - t_val
        ctx.stats["frozen_s"] = (ctx.stats["pin_pause_s"]
                                 + ctx.stats["validate_pause_s"])
        ctx.stats["locked_total_s"] = ctx.stats["frozen_s"]
        eng.device_plugin.lock.unlock()                    # resume
        backend.end_tracking()
        self._tracker.reset()
        eng.registry.exit_all("dump", True)
        t_begin = ctx.stats.pop("t_begin", t_val)
        ctx.stats["total_s"] = time.perf_counter() - t_begin
        eng._concurrent = None
        self._done = True
        eng._after_commit(ctx, path)
        eng.last_stats = dict(ctx.stats)
        eng._write_error = None
        eng.last_commit_step = ctx.step
        return path

    def _writer_post_commit_stats(self, ctx: HookContext) -> None:
        eng = self._engine
        ctx.stats["write_s"] = ctx.stats.get("speculate_s", 0.0)
        eng._writer_stats(ctx, self._writer)

    # -------------------------------------------------------------- abort
    def abort(self) -> None:
        """Discard the capture: stop speculation, delete the open stripe
        set, resume tracking-free.  The job never observes it."""
        if self._done:
            return
        self._cleanup(unlock=False)

    def _cleanup(self, unlock: bool) -> None:
        eng = self._engine
        self._stop.set()
        self._thread.join(timeout=30.0)
        try:
            self._writer.abort()
        except Exception:
            pass
        eng.device_plugin.end_tracking()
        self._tracker.reset()
        if unlock:
            try:
                eng.device_plugin.lock.unlock()
            except Exception:
                pass
        eng.registry.exit_all("dump", False)
        eng._concurrent = None
        self._done = True
