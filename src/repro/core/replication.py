"""In-memory / peer-directory snapshot replication (beyond-paper).

Gemini (SOSP'23) checkpoints to local + *remote host memory* so recovery
does not depend on persistent storage surviving the failure.  Our adaptation
replicates the committed snapshot bytes to a peer store:

  * ``DirReplicator`` — a second directory (standing in for a peer host's
    ramdisk / another node's NVMe); restore falls back to it when the
    primary run_dir has no valid image (tested by corrupting the primary).
  * ``MemReplicator`` — a process-local dict (pure in-memory peer).

Both push after manifest commit (so only *valid* images replicate) and can
re-materialise a snapshot directory into a run_dir on pull.
"""
from __future__ import annotations

import os
import shutil
from typing import Dict, Optional

from repro.core.snapshot_io import MANIFEST, snapshot_dir


class DirReplicator:
    def __init__(self, peer_dir: str):
        self.peer_dir = peer_dir
        os.makedirs(peer_dir, exist_ok=True)

    def push(self, run_dir: str, step: int) -> None:
        src = snapshot_dir(run_dir, step)
        dst = snapshot_dir(self.peer_dir, step)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        # copy payload first, manifest last (commit ordering preserved)
        os.makedirs(dst)
        names = sorted(os.listdir(src))
        for n in [n for n in names if n != MANIFEST] + [MANIFEST]:
            shutil.copy2(os.path.join(src, n), os.path.join(dst, n))

    def pull_latest(self, run_dir: str) -> Optional[int]:
        from repro.core.snapshot_io import SnapshotStore
        steps = SnapshotStore(self.peer_dir).list_steps()
        if not steps:
            return None
        step = steps[-1]
        src = snapshot_dir(self.peer_dir, step)
        dst = snapshot_dir(run_dir, step)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        shutil.copytree(src, dst)
        return step


class MemReplicator:
    def __init__(self):
        self.images: Dict[int, Dict[str, bytes]] = {}

    def push(self, run_dir: str, step: int) -> None:
        src = snapshot_dir(run_dir, step)
        blob = {}
        for n in os.listdir(src):
            with open(os.path.join(src, n), "rb") as f:
                blob[n] = f.read()
        self.images[step] = blob

    def pull_latest(self, run_dir: str) -> Optional[int]:
        if not self.images:
            return None
        step = max(self.images)
        dst = snapshot_dir(run_dir, step)
        os.makedirs(dst, exist_ok=True)
        blob = self.images[step]
        for n in [n for n in blob if n != MANIFEST] + [MANIFEST]:
            with open(os.path.join(dst, n), "wb") as f:
                f.write(blob[n])
        return step
