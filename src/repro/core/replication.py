"""In-memory / peer-directory snapshot replication (beyond-paper).

Gemini (SOSP'23) checkpoints to local + *remote host memory* so recovery
does not depend on persistent storage surviving the failure.  Our adaptation
replicates the committed snapshot bytes to a peer store:

  * ``DirReplicator`` — a second directory (standing in for a peer host's
    ramdisk / another node's NVMe); restore falls back to it when the
    primary run_dir has no valid image (tested by corrupting the primary).
  * ``MemReplicator`` — a process-local dict (pure in-memory peer).

Both push after manifest commit (so only *valid* images replicate) and can
re-materialise a snapshot directory into a run_dir on pull.

``DirReplicator`` pushes are O(delta), not O(image): a file already at the
peer with the same size and mtime is skipped (``copy2`` preserves mtime,
so a replica's fingerprint matches its source until the source changes).
Committed snapshots are immutable, so on an incremental chain this turns
re-pushes and shared-parent pushes into metadata stats.  The skip/copy
counters surface in ``last_stats`` (and, via the engine, in
``last_stats["replica_files_skipped"]`` etc. of the dump).

For cross-host transfer that dedups at *chunk* grain against a
content-addressed store, see :class:`repro.transfer.DeltaReplicator` —
same ``push``/``pull_latest`` contract.

The contract itself is the :class:`Replicator` protocol below: engine,
lazy-restore, and migration code dispatch on **capability**
(``supports_rounds``), never on ``isinstance`` of a concrete replicator.
"""
from __future__ import annotations

import os
import shutil
from typing import (Any, Dict, Optional, Protocol, runtime_checkable)

from repro.core.snapshot_io import MANIFEST, snapshot_dir


@runtime_checkable
class Replicator(Protocol):
    """What the engine and the migration plane require of a replicator.

    push(run_dir, step)   ship one committed snapshot to the peer; returns
                          a stats dict (implementation-specific counters)
                          or None.
    pull(run_dir, step)   re-materialize one snapshot from the peer over
                          the local copy (the heal path); returns the step
                          or None when the peer has no such image.
    pull_latest(run_dir)  materialize the peer's newest image; returns its
                          step or None.
    stats                 the last push's counters (empty dict before any
                          push).
    supports_rounds       capability flag: True when the replicator can
                          run iterative pre-copy rounds (``push_round`` /
                          ``round_state`` — only content-addressed
                          replicators can diff round i against round i-1).
                          Callers gate migration pre-copy on this instead
                          of ``isinstance(rep, DeltaReplicator)``.
    """

    def push(self, run_dir: str, step: int) -> Optional[Dict[str, Any]]:
        ...

    def pull(self, run_dir: str, step: int) -> Optional[int]:
        ...

    def pull_latest(self, run_dir: str) -> Optional[int]:
        ...

    @property
    def stats(self) -> Dict[str, Any]:
        ...

    @property
    def supports_rounds(self) -> bool:
        ...


def _same_file(src: str, dst: str) -> bool:
    """Unchanged replica fingerprint: same size + same mtime (copy2
    preserves mtime, and committed pack files are never rewritten)."""
    try:
        s, d = os.stat(src), os.stat(dst)
    except OSError:
        return False
    return s.st_size == d.st_size and abs(s.st_mtime - d.st_mtime) < 1e-6


class DirReplicator:
    supports_rounds = False    # whole-file diffing: no per-chunk rounds

    def __init__(self, peer_dir: str):
        self.peer_dir = peer_dir
        os.makedirs(peer_dir, exist_ok=True)
        self.last_stats: Dict[str, Any] = {}

    @property
    def stats(self) -> Dict[str, Any]:
        return self.last_stats

    def push(self, run_dir: str, step: int) -> Dict[str, Any]:
        src = snapshot_dir(run_dir, step)
        dst = snapshot_dir(self.peer_dir, step)
        os.makedirs(dst, exist_ok=True)
        names = sorted(os.listdir(src))
        stats = {"files_copied": 0, "files_skipped": 0,
                 "bytes_copied": 0, "bytes_skipped": 0}
        payload = [n for n in names if n != MANIFEST]
        changed = [n for n in payload + [MANIFEST]
                   if not _same_file(os.path.join(src, n),
                                     os.path.join(dst, n))]
        stale = set(os.listdir(dst)) - set(names)
        if changed or stale:
            # the peer must never hold a committed manifest over payload
            # that is mid-replacement: drop its manifest first, then
            # prune/copy, then re-commit the manifest last
            try:
                os.remove(os.path.join(dst, MANIFEST))
            except OSError:
                pass
            if MANIFEST not in changed:
                changed.append(MANIFEST)   # just unlinked: must re-land
        for n in sorted(stale):
            os.remove(os.path.join(dst, n))
        for n in payload + [MANIFEST]:
            sp, dp = os.path.join(src, n), os.path.join(dst, n)
            if n not in changed:
                stats["files_skipped"] += 1
                stats["bytes_skipped"] += os.path.getsize(sp)
                continue
            tmp = dp + ".tmp"
            shutil.copy2(sp, tmp)          # atomic per file: copy + rename
            os.replace(tmp, dp)
            stats["files_copied"] += 1
            stats["bytes_copied"] += os.path.getsize(sp)
        self.last_stats = stats
        return stats

    def pull(self, run_dir: str, step: int) -> Optional[int]:
        """Re-materialize one snapshot from the peer over the local copy
        — the heal path a lazy background stream uses when it hits a torn
        chunk (the replica pushed at commit time is known-good)."""
        src = snapshot_dir(self.peer_dir, step)
        if not os.path.exists(os.path.join(src, MANIFEST)):
            return None
        dst = snapshot_dir(run_dir, step)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        os.makedirs(os.path.dirname(dst), exist_ok=True)
        shutil.copytree(src, dst)
        return step

    def pull_latest(self, run_dir: str) -> Optional[int]:
        from repro.core.snapshot_io import SnapshotStore
        steps = SnapshotStore(self.peer_dir).list_steps()
        if not steps:
            return None
        return self.pull(run_dir, steps[-1])


class MemReplicator:
    supports_rounds = False

    def __init__(self):
        self.images: Dict[int, Dict[str, bytes]] = {}
        self.last_stats: Dict[str, Any] = {}

    @property
    def stats(self) -> Dict[str, Any]:
        return self.last_stats

    def push(self, run_dir: str, step: int) -> None:
        src = snapshot_dir(run_dir, step)
        blob = {}
        for n in os.listdir(src):
            with open(os.path.join(src, n), "rb") as f:
                blob[n] = f.read()
        self.images[step] = blob
        self.last_stats = {"files_copied": len(blob),
                           "bytes_copied": sum(len(b) for b in
                                               blob.values())}

    def pull(self, run_dir: str, step: int) -> Optional[int]:
        if step not in self.images:
            return None
        dst = snapshot_dir(run_dir, step)
        if os.path.isdir(dst):
            shutil.rmtree(dst)
        os.makedirs(dst, exist_ok=True)
        blob = self.images[step]
        for n in [n for n in blob if n != MANIFEST] + [MANIFEST]:
            with open(os.path.join(dst, n), "wb") as f:
                f.write(blob[n])
        return step

    def pull_latest(self, run_dir: str) -> Optional[int]:
        if not self.images:
            return None
        return self.pull(run_dir, max(self.images))
