"""Versioned device-backend registry — the CUDA/ROCm plugin split.

CRIUgpu registers its CUDA and AMD/KFD plugins against the CRIU plugin API
and CRIU picks whichever matches the hardware; the plugin carries a version
stamp so a CRIU built for a different plugin ABI refuses to load it
(paper §3.1.3).  We mirror that: a ``DeviceBackend`` is a named, versioned,
feature-stamped plugin that owns the device side of the dump/restore hook
sequence, and the registry here maps names to factories:

  "jax"   — the JAX-array backend (``DevicePlugin``): device lock, shard
            dedup, sharded/elastic restore.  The CUDA-analogue default.
  "host"  — host-numpy fallback: captures every leaf as host memory and
            restores without touching devices.  Used by the CLI's
            ``restore --dry-run`` and by environments where device
            placement is unavailable or unwanted.

Backends register with the ``api_version`` they were built against; a
mismatch is rejected at registration (and again by ``PluginRegistry.add``),
so a stale backend can never silently corrupt an image.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, FrozenSet, Iterable

from repro.core.plugins import (PLUGIN_API_VERSION, HookContext,
                                Plugin, PluginVersionError)

try:  # Protocol is typing-only sugar; keep the module importable anywhere
    from typing import Protocol, runtime_checkable
except ImportError:  # pragma: no cover
    Protocol = object

    def runtime_checkable(cls):
        return cls


class BackendError(RuntimeError):
    """Unknown backend name or invalid registration."""


#: Feature flags of the "jax" backend; DevicePlugin.features references
#: this so the registration below and the plugin stamp cannot drift.
JAX_BACKEND_FEATURES = frozenset({
    "device_arrays", "sharded_restore", "parallel_restore",
    "elastic_restore", "replica_dedup", "chunked_packs", "pipelined_io",
    "dirty_tracking"})


class DirtyTrackingMixin:
    """Concurrent-capture (soft-freeze) protocol shared by backends that
    advertise the "dirty_tracking" feature.

    Four pieces: a flat keyed view of the live roots (``flatten_keys``),
    single-leaf capture (``capture_entry``), wiring a
    :class:`repro.core.dirty.DirtyTracker` to stream retirements
    (``begin_tracking``/``end_tracking``), and the explicit CRAC-style
    capture boundary (``attach_streams``/``drain_streams`` — every
    capture pause drains the injectable fake streams and fails fast with
    :class:`repro.core.streams.UnsafeOpInFlight` if an op cannot be
    quiesced, instead of snapshotting torn state).
    """

    streams = None            # Optional[repro.core.streams.StreamSet]
    _tracker = None

    def attach_streams(self, streams) -> None:
        """Install the injectable fake-stream plane (tests, sims, the
        host backend's async-dispatch model)."""
        self.streams = streams

    def drain_streams(self) -> None:
        """Quiesce the capture boundary; raises UnsafeOpInFlight on a
        stuck op.  Called under the device lock at every pause."""
        if self.streams is None:
            return
        from repro.core.streams import UnsafeOpInFlight
        stuck = self.streams.drain()
        if stuck:
            raise UnsafeOpInFlight(stuck)

    def flatten_keys(self, roots: Dict[str, Any]) -> Dict[str, Any]:
        """roots -> {"state::path": leaf} in capture order."""
        from repro.core.device_plugin import flatten_with_paths
        out: Dict[str, Any] = {}
        for name, tree in roots.items():
            for key, leaf in flatten_with_paths(tree).items():
                out[f"{name}::{key}"] = leaf
        return out

    def capture_entry(self, leaf: Any) -> Dict[str, Any]:
        """Capture one leaf into a snapshot entry dict.  Overridden by
        the jax backend to capture device arrays shard-by-shard."""
        import numpy as np
        if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
            return {"kind": "np", "data": np.asarray(leaf)}
        return {"kind": "host", "value": leaf}

    def begin_tracking(self, tracker) -> None:
        """Route stream retirements into the dirty set for the duration
        of a concurrent capture."""
        self._tracker = tracker
        if self.streams is not None:
            self.streams.on_retire = (
                lambda op: tracker.note_many(op.targets))

    def end_tracking(self) -> None:
        self._tracker = None
        if self.streams is not None:
            self.streams.on_retire = None


@runtime_checkable
class DeviceBackend(Protocol):
    """The device side of the checkpoint contract.

    Structural protocol extracted from ``DevicePlugin``: any Plugin that
    implements the three device hooks (pause / checkpoint / resume-late)
    plus the identity stamps can serve as the engine's device backend.
    """

    name: str
    api_version: int
    features: FrozenSet[str]

    def pause_devices(self, ctx: HookContext) -> None: ...
    def checkpoint_devices(self, ctx: HookContext) -> None: ...
    def update_topology_map(self, ctx: HookContext) -> None: ...
    def resume_devices_late(self, ctx: HookContext) -> None: ...


@dataclasses.dataclass(frozen=True)
class BackendSpec:
    name: str
    factory: Callable[..., Plugin]
    api_version: int
    features: FrozenSet[str]
    description: str = ""


_REGISTRY: Dict[str, BackendSpec] = {}


def register_backend(name: str, factory: Callable[..., Plugin], *,
                     api_version: int,
                     features: Iterable[str] = (),
                     description: str = "",
                     override: bool = False) -> BackendSpec:
    """Register a device backend under `name`.

    Rejects (PluginVersionError) backends declaring an api_version other
    than the one this engine speaks — the CRIU "plugin built for another
    CRIU" refusal, at registration time rather than dump time.
    """
    if api_version != PLUGIN_API_VERSION:
        raise PluginVersionError(
            f"backend {name!r} declares api_version={api_version}; "
            f"this engine speaks api_version={PLUGIN_API_VERSION}")
    if name in _REGISTRY and not override:
        raise BackendError(f"backend {name!r} already registered")
    spec = BackendSpec(name=name, factory=factory, api_version=api_version,
                       features=frozenset(features),
                       description=description)
    _REGISTRY[name] = spec
    return spec


def create_backend(name: str, **kwargs) -> Plugin:
    """Instantiate a registered backend by name."""
    try:
        spec = _REGISTRY[name]
    except KeyError:
        raise BackendError(
            f"unknown device backend {name!r}; "
            f"registered: {sorted(_REGISTRY)}") from None
    plugin = spec.factory(**kwargs)
    if getattr(plugin, "api_version", None) != PLUGIN_API_VERSION:
        raise PluginVersionError(
            f"backend {name!r} produced a plugin with "
            f"api_version={getattr(plugin, 'api_version', None)!r}")
    plugin.backend_name = name       # registry name (plugin.name may differ)
    return plugin


def available_backends() -> Dict[str, Dict[str, Any]]:
    """name -> {api_version, features, description} for capability reports."""
    return {n: {"api_version": s.api_version,
                "features": sorted(s.features),
                "description": s.description}
            for n, s in sorted(_REGISTRY.items())}


# ---------------------------------------------------------------- host
class HostNumpyBackend(DirtyTrackingMixin, Plugin):
    """Device backend that never touches an accelerator.

    Capture converts every array leaf to host numpy (one logical shard);
    restore materialises numpy arrays and leaves device placement to the
    caller.  This is the "no driver" path: image surgery, CLI dry-run
    restores, and CI machines without working accelerator runtimes.
    """

    name = "host"
    api_version = PLUGIN_API_VERSION
    features = frozenset({"host_arrays", "dry_run_restore",
                          "chunked_packs", "pipelined_io",
                          "dirty_tracking"})

    def __init__(self, lock_timeout_s: float = 10.0,
                 restore_threads: int = 0):
        # same constructor surface as the jax backend so the engine can
        # build either from one options object
        from repro.core.lock import DeviceLock
        self.lock = DeviceLock(lock_timeout_s)
        self.restore_threads = restore_threads
        self.streams = None

    # --- dump ---
    def pause_devices(self, ctx: HookContext) -> None:
        ctx.stats["lock_s"] = self.lock.lock([])
        self.drain_streams()       # CRAC boundary: may raise UnsafeOp

    def checkpoint_devices(self, ctx: HookContext) -> None:
        import numpy as np
        t0 = time.perf_counter()
        host_bytes = 0
        for name, tree in getattr(ctx, "roots", {}).items():
            from repro.core.device_plugin import flatten_with_paths
            cap: Dict[str, Any] = {}
            for key, leaf in flatten_with_paths(tree).items():
                if hasattr(leaf, "shape") and hasattr(leaf, "dtype"):
                    arr = np.asarray(leaf)
                    cap[key] = {"kind": "np", "data": arr}
                    host_bytes += arr.nbytes
                else:
                    cap[key] = {"kind": "host", "value": leaf}
            ctx.device_snapshot[name] = cap
        ctx.stats["device_to_host_s"] = time.perf_counter() - t0
        ctx.stats["capture_s"] = ctx.stats["device_to_host_s"]
        ctx.stats["device_bytes"] = float(host_bytes)

    # --- restore ---
    def update_topology_map(self, ctx: HookContext) -> None:
        ctx.topology_map["mode"] = "host"
        ctx.topology_map["target"] = None

    @staticmethod
    def _place_entry(reader, state: str, path: str):
        from repro.core.device_plugin import assemble_global
        entry = reader.load_entry(state, path)
        if entry["kind"] == "device_array":
            return assemble_global(entry)
        if entry["kind"] == "np":
            return entry["data"]
        return entry["value"]

    def resume_devices_late(self, ctx: HookContext) -> None:
        from repro.core.device_plugin import _unflatten_paths, assemble_global
        t0 = time.perf_counter()
        place_s = 0.0
        reader = ctx.reader
        threads = getattr(ctx, "restore_threads", 0) or self.restore_threads
        if getattr(ctx, "lazy", False):
            from repro.core.lazy import resume_with_schedule
            resume_with_schedule(ctx, self._place_entry, threads)
            self.lock.unlock()                        # resume on criticals
            ctx.stats["host_to_device_s"] = time.perf_counter() - t0
            ctx.stats["place_s"] = ctx.stats.get("place_critical_s", 0.0)
            return
        for name in reader.state_names():
            keys = reader.entry_names(name)
            if threads > 1 and len(keys) > 1:
                from concurrent.futures import ThreadPoolExecutor
                with ThreadPoolExecutor(max_workers=threads) as ex:
                    entries = list(ex.map(
                        lambda k: reader.load_entry(name, k), keys))
            else:
                entries = [reader.load_entry(name, k) for k in keys]
            restored: Dict[str, Any] = {}
            t_place = time.perf_counter()
            for key, entry in zip(keys, entries):
                if entry["kind"] == "device_array":
                    restored[key] = assemble_global(entry)
                elif entry["kind"] == "np":
                    restored[key] = entry["data"]
                else:
                    restored[key] = entry["value"]
            place_s += time.perf_counter() - t_place
            ctx.restored[name] = _unflatten_paths(restored)
        self.lock.unlock()
        ctx.stats["host_to_device_s"] = time.perf_counter() - t0
        ctx.stats["place_s"] = place_s


def _make_jax_backend(**kwargs) -> Plugin:
    from repro.core.device_plugin import DevicePlugin
    return DevicePlugin(**kwargs)


register_backend(
    "jax", _make_jax_backend, api_version=PLUGIN_API_VERSION,
    features=JAX_BACKEND_FEATURES,
    description="JAX-array device backend (lock, shard dedup, elastic "
                "restore) — the CUDA-plugin analogue")

register_backend(
    "host", HostNumpyBackend, api_version=PLUGIN_API_VERSION,
    features=HostNumpyBackend.features,
    description="host-numpy fallback: capture/restore without touching "
                "devices (CLI dry-run, driverless environments)")
