"""Topology fingerprints + translation (the GPUID-translation analogue).

The AMD plugin translates GPUIDs so a checkpoint taken on one set of GPUs
restores on a different (compatible) set (paper §3.1.2); the CUDA plugin
requires identical GPU type/order (§4.4).  Our adaptation fingerprints the
*mesh* (shape, axis names, device kind, process count) and supports three
restore modes:

  identical   — same fingerprint: shards are device_put 1:1 (fast path)
  translated  — same logical mesh, different device ids/order: the
                UPDATE_TOPOLOGY_MAP hook remaps shard -> device
  resharded   — different mesh (elastic restore): global arrays are
                reassembled from saved shards and re-laid-out onto the new
                mesh; this is the capability the paper's GPU stack lacks and
                flags as future work — on the JAX side it falls out of the
                sharding model, and is our elastic-scaling path.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec


def mesh_fingerprint(mesh: Optional[Mesh]) -> Dict[str, Any]:
    if mesh is None:
        devs = jax.devices()
        return {"kind": devs[0].device_kind, "n_devices": len(devs),
                "mesh_shape": None, "mesh_axes": None,
                "process_count": jax.process_count()}
    devs = mesh.devices.reshape(-1)
    return {
        "kind": devs[0].device_kind,
        "n_devices": int(devs.size),
        "mesh_shape": [int(s) for s in mesh.devices.shape],
        "mesh_axes": list(mesh.axis_names),
        "process_count": jax.process_count(),
    }


def compatibility(saved: Dict[str, Any], target: Dict[str, Any]) -> str:
    if saved == target:
        return "identical"
    if (saved.get("mesh_shape") == target.get("mesh_shape")
            and saved.get("mesh_axes") == target.get("mesh_axes")):
        return "translated"
    return "resharded"


# ---------------------------------------------------------------- specs
def spec_to_json(spec: PartitionSpec) -> list:
    out = []
    for e in tuple(spec):
        if e is None:
            out.append(None)
        elif isinstance(e, (tuple, list)):
            out.append(list(e))
        else:
            out.append([e])
    return out


def spec_from_json(j) -> PartitionSpec:
    ents = []
    for e in j:
        if e is None:
            ents.append(None)
        elif len(e) == 1:
            ents.append(e[0])
        else:
            ents.append(tuple(e))
    return PartitionSpec(*ents)


def sharding_descriptor(arr: jax.Array) -> Dict[str, Any]:
    sh = arr.sharding
    if isinstance(sh, NamedSharding):
        return {"type": "named",
                "mesh": mesh_fingerprint(sh.mesh),
                "spec": spec_to_json(sh.spec)}
    return {"type": "other", "mesh": None, "spec": None}


def resolve_sharding(desc: Dict[str, Any], target_mesh: Optional[Mesh]):
    """Translate a saved sharding descriptor onto the target mesh (the
    UPDATE_TOPOLOGY_MAP step).  Returns None when no mapping is possible
    (caller falls back to replicated / single-device placement)."""
    if target_mesh is None or desc.get("type") != "named":
        return None
    spec = spec_from_json(desc["spec"])
    axes = set(target_mesh.axis_names)
    # drop references to axes the new mesh doesn't have (elastic downsizing)
    ents = []
    for e in tuple(spec):
        if e is None:
            ents.append(None)
        elif isinstance(e, tuple):
            kept = tuple(a for a in e if a in axes)
            ents.append(kept if kept else None)
        else:
            ents.append(e if e in axes else None)
    return NamedSharding(target_mesh, PartitionSpec(*ents))
