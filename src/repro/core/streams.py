"""Injectable fake streams — the CRAC-style explicit capture boundary.

JAX dispatches asynchronously: a step can return while transfers, donated
buffers, and collectives are still in flight.  On real devices `freeze()`
drains this implicitly via ``block_until_ready``; for the host backend —
and for the concurrent soft-freeze capture, where the step loop *keeps
running* during the snapshot — the boundary must be explicit and testable.

``StreamSet`` models per-stream queues of ``StreamOp``s the workload (or a
test, or the chaos plane) enqueues to simulate async dispatch, host-to-
device prefetch, buffer donation, and cross-host collectives.  The engine
drains every stream at each capture pause:

  * quiescable ops are applied (their side effects land, like a real
    ``block_until_ready``) and retired;
  * a non-quiescable op — one that cannot be completed at a safe point,
    e.g. a collective whose peers are wedged — makes the pause fail fast
    with :class:`UnsafeOpInFlight` instead of snapshotting torn state.

Retirements are reported through ``on_retire`` so a dirty tracker can note
which entries an op mutated between the pin and validate pauses.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, List, Optional, Sequence, Tuple


class UnsafeOpInFlight(RuntimeError):
    """A capture pause found async work that cannot be quiesced."""

    def __init__(self, ops: Sequence["StreamOp"]):
        self.ops = tuple(ops)
        names = ", ".join(f"{o.stream or '?'}:{o.kind}" for o in self.ops)
        super().__init__(
            f"unsafe op in flight at capture boundary: {names} "
            f"({len(self.ops)} op(s) could not be quiesced — refusing "
            f"to snapshot torn state)")


class StreamOp:
    """One in-flight async operation.

    kind        free-form tag ("dispatch", "prefetch", "donate",
                "collective", ...) — used in diagnostics.
    targets     entry keys ("state::path") this op mutates when it
                retires; fed to the dirty tracker.
    apply       optional side effect run at retirement (mutates live
                state the way a completing transfer would).
    quiescable  False marks an op that cannot complete at a capture
                boundary; draining it raises UnsafeOpInFlight.
    """

    __slots__ = ("kind", "targets", "apply", "quiescable", "stream")

    def __init__(self, kind: str, targets: Sequence[str] = (),
                 apply: Optional[Callable[[], None]] = None,
                 quiescable: bool = True):
        self.kind = kind
        self.targets = tuple(targets)
        self.apply = apply
        self.quiescable = quiescable
        self.stream: Optional[str] = None  # stamped on enqueue


class FakeStream:
    """An ordered queue of StreamOps, retired FIFO like a device stream."""

    def __init__(self, name: str):
        self.name = name
        self._ops: List[StreamOp] = []

    def enqueue(self, op: StreamOp) -> StreamOp:
        op.stream = self.name
        self._ops.append(op)
        return op

    def pending(self) -> Tuple[StreamOp, ...]:
        return tuple(self._ops)

    def retire_ready(self, on_retire) -> List[StreamOp]:
        """Retire quiescable ops in order; stop at the first stuck one
        (a device stream cannot reorder past a blocked op)."""
        stuck: List[StreamOp] = []
        while self._ops:
            op = self._ops[0]
            if not op.quiescable:
                stuck.append(op)
                break
            self._ops.pop(0)
            if op.apply is not None:
                op.apply()
            if on_retire is not None:
                on_retire(op)
        return stuck


class StreamSet:
    """The backend's view of every injectable stream.

    Thread-safe: the step loop enqueues while the engine's capture
    thread drains.  ``on_retire`` (set by the backend when tracking
    starts) receives each retired op so its targets land in the dirty
    set.
    """

    def __init__(self):
        self._streams: Dict[str, FakeStream] = {}
        self._lock = threading.Lock()
        self.on_retire: Optional[Callable[[StreamOp], None]] = None

    def stream(self, name: str) -> FakeStream:
        with self._lock:
            s = self._streams.get(name)
            if s is None:
                s = self._streams[name] = FakeStream(name)
            return s

    def enqueue(self, name: str, op: StreamOp) -> StreamOp:
        with self._lock:
            s = self._streams.get(name)
            if s is None:
                s = self._streams[name] = FakeStream(name)
            return s.enqueue(op)

    def pending_ops(self) -> List[StreamOp]:
        with self._lock:
            return [op for s in self._streams.values()
                    for op in s.pending()]

    def drain(self) -> List[StreamOp]:
        """Retire everything retirable; return the stuck ops (empty =
        fully quiesced).  Caller decides whether stuck is fatal."""
        with self._lock:
            stuck: List[StreamOp] = []
            for s in self._streams.values():
                stuck.extend(s.retire_ready(self.on_retire))
            return stuck

    def clear_stuck(self) -> int:
        """Drop non-quiescable ops (test/chaos cleanup after an
        aborted dump); returns how many were dropped."""
        dropped = 0
        with self._lock:
            for s in self._streams.values():
                kept = [op for op in s._ops if op.quiescable]
                dropped += len(s._ops) - len(kept)
                s._ops = kept
        return dropped
