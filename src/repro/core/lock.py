"""Device quiesce ("lock") — the cuda-checkpoint lock/unlock analogue.

``cuda-checkpoint --action lock`` blocks new CUDA API calls and waits for
in-flight work (stream callbacks etc.) to finish, with a timeout after which
CRIUgpu rolls everything back to the running state (paper §3.1.1).

The JAX runtime analogue: in-flight work is the async-dispatch queue behind
every live ``jax.Array``; draining it (``block_until_ready``) guarantees no
computation is mutating device state while we snapshot.  New dispatch cannot
race us because the engine owns the only dispatching thread while locked —
the single-controller equivalent of blocking the driver API.  The timeout +
abort semantics are preserved: if the drain does not finish in time we raise
and the engine restores the "running" state (i.e. gives up the checkpoint).
"""
from __future__ import annotations

import threading
import time
from typing import Any, List

import jax


class LockTimeout(RuntimeError):
    pass


class DeviceLock:
    def __init__(self, timeout_s: float = 10.0):
        self.timeout_s = timeout_s
        self.locked = False
        self.lock_time_s = 0.0

    def lock(self, arrays: List[Any]) -> float:
        """Drain async dispatch for `arrays`.  Returns the drain time."""
        t0 = time.perf_counter()
        err: List[BaseException] = []

        def drain():
            try:
                jax.block_until_ready(arrays)
            except BaseException as e:               # pragma: no cover
                err.append(e)

        t = threading.Thread(target=drain, daemon=True)
        t.start()
        t.join(self.timeout_s)
        if t.is_alive():
            raise LockTimeout(
                f"device quiesce exceeded {self.timeout_s}s "
                f"(in-flight work still running); aborting checkpoint")
        if err:
            raise err[0]
        self.locked = True
        self.lock_time_s = time.perf_counter() - t0
        return self.lock_time_s

    def lock_all_live(self) -> float:
        """Global quiesce over every live array in the process — the
        whole-process lock cuda-checkpoint applies."""
        return self.lock(list(jax.live_arrays()))

    def unlock(self) -> None:
        self.locked = False
