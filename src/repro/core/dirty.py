"""Dirty-set protocol for concurrent (soft-freeze) capture.

The pin pause records, per entry key ("state::path"), a strong reference
to the live leaf plus its identity.  While the engine speculates shards
to disk the step loop keeps mutating state; at the validate pause the
tracker answers one question: *which entries might differ from what was
speculated?*  Three signals feed the answer:

  * identity drift — the leaf object at a pinned path changed identity
    (functional updates, donation: jax rebinds arrays rather than
    mutating them);
  * explicit notes — stream retirements and chaos faults call
    :meth:`note` for entries they mutated in place (np.ndarrays mutate
    without identity change);
  * structural drift — a pinned path disappeared from the live tree
    (deleted/renamed entries can never validate).

The dirty set is deliberately an over-approximation: a dirty entry is
merely *re-hashed* against the speculated chunk CRCs, and only actual
mismatches are re-captured.  Missing a mutation, by contrast, would
commit torn state — so every "maybe" lands in the set.
"""
from __future__ import annotations

import threading
from typing import Dict, Set


class DirtyTracker:
    """Tracks which pinned entries may have been mutated mid-capture."""

    def __init__(self):
        self._lock = threading.Lock()
        self._pinned: Dict[str, object] = {}      # key -> leaf (strong ref)
        self._identities: Dict[str, int] = {}     # key -> id(leaf) at pin
        self._noted: Set[str] = set()
        self._active = False

    # -------------------------------------------------------------- pin
    def pin(self, leaves: Dict[str, object]) -> None:
        """Record the capture-time tree: key -> live leaf.  Strong refs
        keep donated-away buffers alive until speculation reads them."""
        with self._lock:
            self._pinned = dict(leaves)
            self._identities = {k: id(v) for k, v in leaves.items()}
            self._noted = set()
            self._active = True

    @property
    def active(self) -> bool:
        return self._active

    def pinned(self) -> Dict[str, object]:
        with self._lock:
            return dict(self._pinned)

    # ------------------------------------------------------------- notes
    def note(self, key: str) -> None:
        """An entry was mutated in place (stream retirement, chaos)."""
        with self._lock:
            if self._active:
                self._noted.add(key)

    def note_many(self, keys) -> None:
        with self._lock:
            if self._active:
                self._noted.update(keys)

    # ---------------------------------------------------------- validate
    def dirty_keys(self, live_leaves: Dict[str, object]) -> Set[str]:
        """Pinned entries that may differ from the speculated bytes:
        noted in-place mutations, identity drift, and deletions."""
        with self._lock:
            dirty = set(self._noted)
            for key, ident in self._identities.items():
                live = live_leaves.get(key, _MISSING)
                if live is _MISSING or id(live) != ident:
                    dirty.add(key)
            return dirty

    def reset(self) -> None:
        with self._lock:
            self._pinned = {}
            self._identities = {}
            self._noted = set()
            self._active = False


_MISSING = object()
