import os
os.environ["XLA_FLAGS"] = (os.environ.get("_REPRO_EXTRA_XLA", "") +
                           " --xla_force_host_platform_device_count=512")
# ^ MUST run before any jax import: jax locks the device count on first init.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell the FULL published config is lowered with abstract inputs
(ShapeDtypeStruct — no allocation) onto the production mesh, compiled, and
the artifacts recorded for EXPERIMENTS.md:

  * ``compiled.memory_analysis()``  — proves the layout fits HBM
  * ``compiled.cost_analysis()``    — HLO FLOPs / bytes for §Roofline
  * collective-op byte census parsed from the partitioned HLO
    (all-gather / all-reduce / reduce-scatter / all-to-all /
    collective-permute) — the §Roofline collective term.

Usage:
  python -m repro.launch.dryrun --arch qwen1.5-0.5b --shape train_4k \
      --mesh pod|multipod [--policy baseline] [--out artifacts/dryrun]
  python -m repro.launch.dryrun --all [--mesh both]   # subprocess per cell
"""
import argparse
import json
import re
import subprocess
import sys
import time
from typing import Any, Dict

# TPU v5e-class hardware constants (roofline targets; CPU is the host here)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / ICI link

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
         "collective-permute")
_COLL_RE = re.compile(
    r"=\s*(?P<restype>.+?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<variant>-start)?\(",
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _bytes_of(restype: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(restype):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str, n_devices: int) -> Dict[str, Any]:
    """Per-device collective byte census from partitioned HLO."""
    out = {op: {"count": 0, "bytes": 0.0, "wire_bytes": 0.0}
           for op in _COLL}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        nbytes = _bytes_of(m.group("restype"))
        g = n_devices
        mg = _GROUPS_RE.search(line)
        if mg:
            g = len(mg.group(1).split(","))
        else:
            mi = _GROUPS_IOTA_RE.search(line)
            if mi:
                g = int(mi.group(2))
        g = max(g, 1)
        # ring-algorithm wire bytes per device
        if op == "all-gather":
            wire = nbytes * (g - 1) / g
        elif op == "all-reduce":
            wire = 2.0 * nbytes * (g - 1) / g
        elif op == "reduce-scatter":
            wire = nbytes * (g - 1)          # result is the scattered shard
        elif op == "all-to-all":
            wire = nbytes * (g - 1) / g
        else:                                 # collective-permute
            wire = float(nbytes)
        out[op]["count"] += 1
        out[op]["bytes"] += float(nbytes)
        out[op]["wire_bytes"] += wire
    out["total_wire_bytes"] = sum(v["wire_bytes"] for k, v in out.items()
                                  if isinstance(v, dict))
    out["total_count"] = sum(v["count"] for k, v in out.items()
                             if isinstance(v, dict))
    return out


# ----------------------------------------------------------------------
VARIANTS = ("base", "bf16score", "xentchunk", "noremat", "gqaexpand",
            "bf16cast", "gradbf16", "gqaexpand_bf16cast",
            "gqaexpand_bf16cast_gradbf16", "opt")


_KNOBS = {"base", "bf16score", "xentchunk", "noremat", "gqaexpand",
          "bf16cast", "gradbf16"}


def variant_parts(variant: str) -> set:
    if variant == "opt":        # every winning knob (see EXPERIMENTS.md)
        return {"gqaexpand", "bf16cast", "gradbf16", "xentchunk"}
    parts = set(variant.split("_"))
    unknown = parts - _KNOBS
    if unknown:
        raise ValueError(f"unknown variant knob(s) {sorted(unknown)}; "
                         f"known: {sorted(_KNOBS)}")
    return parts


def apply_variant(variant: str) -> bool:
    """§Perf hillclimb knobs (module-level, applied before tracing).
    Variants compose with '_'; 'opt' = every winning knob.  Returns the
    remat setting the variant implies."""
    import jax.numpy as jnp
    from repro.models import layers as L
    parts = variant_parts(variant)
    L.SCORE_DTYPE = jnp.bfloat16 if "bf16score" in parts else jnp.float32
    L.XENT_SEQ_CHUNK = 512 if "xentchunk" in parts else 0
    L.GQA_EXPAND = "gqaexpand" in parts
    L.CAST_PARAMS_ONCE = "bf16cast" in parts
    return "noremat" not in parts


def build_lowered(arch: str, shape: str, mesh, policy_name: str,
                  remat: bool = True, variant: str = "base"):
    """Construct and lower the jitted target for one cell."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P

    if variant != "base":
        remat = apply_variant(variant) and remat

    from repro.configs import get_config
    from repro.launch.mesh import use_mesh
    from repro.launch.shapes import SHAPES, batch_specs, batch_shardings
    from repro.models.encdec import build_model
    from repro.optim import AdamW
    from repro.optim.adamw import OptState
    from repro.optim.schedule import warmup_cosine
    from repro.sharding import get_policy

    from repro.sharding.policy import fit_shardings_tree

    cfg = get_config(arch)
    cell = SHAPES[shape]
    policy = get_policy(policy_name).for_mesh(mesh)
    model = build_model(cfg, policy, mesh, compute_dtype=jnp.bfloat16,
                        remat=remat)
    params_abs = model.init_abstract()
    # divisibility-fit every in_sharding (e.g. whisper d_model=384 cannot
    # shard 256 ways under fsdp_all; prefill batch 32 cannot DP-shard 256
    # ways — the fit degrades to the largest dividing prefix)
    param_sh = fit_shardings_tree(model.param_shardings(), params_abs, mesh)
    scalar_sh = NamedSharding(mesh, P())

    if cell.kind == "train":
        opt = AdamW(lr=warmup_cosine(3e-4, 2000, 100000))
        opt_abs = opt.init_abstract(params_abs)
        opt_sh = OptState(step=scalar_sh, m=param_sh, v=param_sh)
        batch_abs = batch_specs(cfg, cell.global_batch, cell.seq_len)
        batch_sh = fit_shardings_tree(
            batch_shardings(cfg, policy, mesh), batch_abs, mesh)
        grad_bf16 = "gradbf16" in variant_parts(variant)

        def train_step(params, opt_state, batch):
            (loss, metrics), grads = jax.value_and_grad(
                model.loss, has_aux=True)(params, batch)
            if grad_bf16:
                # gradient compression: the cross-replica reduction moves
                # bf16 (half the wire); the optimizer re-upcasts to f32
                grads = jax.tree.map(
                    lambda g: g.astype(jnp.bfloat16).astype(g.dtype), grads)
            params, opt_state, om = opt.update(grads, opt_state, params)
            return params, opt_state, loss, metrics["loss"]

        jitted = jax.jit(train_step,
                         in_shardings=(param_sh, opt_sh, batch_sh),
                         donate_argnums=(0, 1))
        with use_mesh(mesh):
            lowered = jitted.lower(params_abs, opt_abs, batch_abs)
    elif cell.kind == "prefill":
        batch_abs = batch_specs(cfg, cell.global_batch, cell.seq_len)
        batch_sh = fit_shardings_tree(
            batch_shardings(cfg, policy, mesh), batch_abs, mesh)
        jitted = jax.jit(model.prefill, in_shardings=(param_sh, batch_sh))
        with use_mesh(mesh):
            lowered = jitted.lower(params_abs, batch_abs)
    else:                                     # decode / serve_step
        B, S = cell.global_batch, cell.seq_len
        cache_abs = model.cache_abstract(B, S)
        cache_sh = model.cache_shardings(batch=B, max_seq=S)
        tok_sh = (policy.sharding(mesh, "batch")
                  if B % _dp_size(policy, mesh) == 0 and
                  _dp_size(policy, mesh) > 1 else scalar_sh)

        def serve_step(params, cache, tokens, pos):
            return model.decode_step(params, cache, tokens, pos)

        jitted = jax.jit(serve_step,
                         in_shardings=(param_sh, cache_sh, tok_sh,
                                       scalar_sh),
                         donate_argnums=(1,))
        with use_mesh(mesh):
            lowered = jitted.lower(params_abs, cache_abs,
                                   jax.ShapeDtypeStruct((B,), jnp.int32),
                                   jax.ShapeDtypeStruct((), jnp.int32))
    return lowered, cfg, cell


def _dp_size(policy, mesh):
    import numpy as np
    dp = tuple(a for a in policy.dp if a in mesh.axis_names)
    return int(np.prod([mesh.shape[a] for a in dp])) if dp else 1


def analyse(lowered, compiled, cfg, cell, n_devices: int) -> Dict[str, Any]:
    """Three-term roofline from the compiled artifact.

    Primary source: the trip-count-aware HLO analyzer
    (repro.launch.hlo_analysis) — XLA's cost_analysis counts while bodies
    ONCE, undercounting layer-scanned models by ~n_layers; both are
    recorded, the analyzer drives the terms."""
    from repro.launch.hlo_analysis import analyze_hlo, top_buffers

    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    xla_flops = float(cost.get("flops", 0.0))
    xla_bytes = float(cost.get("bytes accessed", 0.0))

    mem: Dict[str, float] = {}
    try:
        ma = compiled.memory_analysis()
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "alias_size_in_bytes",
                     "generated_code_size_in_bytes"):
            v = getattr(ma, attr, None)
            if v is not None:
                mem[attr] = float(v)
    except Exception as e:                    # pragma: no cover
        mem["error"] = str(e)

    hlo_text = compiled.as_text()
    rec_hlo = analyze_hlo(hlo_text, n_devices,
                          seq_len=cell.seq_len
                          if cell.kind in ("train", "prefill") else None)
    flops_dev = rec_hlo["flops"]
    bytes_dev = rec_hlo["bytes"]
    score_bytes = rec_hlo["score_bytes"]
    coll = {k: v for k, v in rec_hlo["collectives"].items()}
    coll["total_wire_bytes"] = rec_hlo["collective_wire_bytes"]
    coll["total_count"] = rec_hlo["collective_count"]

    # roofline terms (per chip)
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    # kernel-substituted memory term: the validated Pallas flash-attention
    # kernel keeps the (S×S) score/prob matrices in VMEM, so their HBM
    # traffic vanishes (q/k/v/o streaming is already counted by the
    # adjacent projection ops).  This is a MODELLED term — Mosaic cannot
    # lower on the CPU container — and is reported alongside the
    # as-compiled term, never silently substituted.
    t_memory_flash = max(bytes_dev - score_bytes, 0.0) / HBM_BW
    t_coll = coll["total_wire_bytes"] / LINK_BW
    dominant = max(("compute", t_compute), ("memory", t_memory),
                   ("collective", t_coll), key=lambda kv: kv[1])[0]

    # MODEL_FLOPS: 6·N·D train, 2·N_active·D inference
    tokens = (cell.global_batch * cell.seq_len
              if cell.kind in ("train", "prefill") else cell.global_batch)
    n_active = cfg.param_count(active_only=True)
    mf = (6.0 if cell.kind == "train" else 2.0) * n_active * tokens
    hlo_global = flops_dev * n_devices
    ideal_s = mf / n_devices / PEAK_FLOPS
    bound = max(t_compute, t_memory, t_coll)
    bound_flash = max(t_compute, t_memory_flash, t_coll)
    return {
        "flops_per_device": flops_dev,
        "bytes_per_device": bytes_dev,
        "score_bytes_per_device": score_bytes,
        "flops_by_kind": rec_hlo["flops_by_kind"],
        "bytes_by_kind": rec_hlo["bytes_by_kind"],
        "top_traffic": rec_hlo["top_traffic"],
        "top_collectives": rec_hlo["top_collectives"],
        "xla_cost_flops": xla_flops,          # while-body-once (reference)
        "xla_cost_bytes": xla_bytes,
        "collectives": coll,
        "memory": mem,
        "top_buffers": top_buffers(hlo_text, 8),
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_memory_flash_s": t_memory_flash,   # modelled (Pallas kernel)
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops": mf,
        "hlo_flops_global": hlo_global,
        "useful_flops_ratio": mf / hlo_global if hlo_global else 0.0,
        "roofline_bound_s": bound,
        "roofline_fraction": ideal_s / bound if bound else 0.0,
        "roofline_fraction_flash": ideal_s / bound_flash if bound_flash
        else 0.0,
    }


def run_cell(arch: str, shape: str, mesh_kind: str, policy: str,
             out_dir: str, remat: bool = True,
             variant: str = "base") -> Dict[str, Any]:
    import jax
    from repro.launch.mesh import make_production_mesh

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    n_dev = int(mesh.devices.size)
    rec: Dict[str, Any] = {"arch": arch, "shape": shape, "mesh": mesh_kind,
                           "policy": policy, "variant": variant,
                           "n_devices": n_dev}
    t0 = time.perf_counter()
    lowered, cfg, cell = build_lowered(arch, shape, mesh, policy,
                                       remat=remat, variant=variant)
    rec["lower_s"] = time.perf_counter() - t0
    t1 = time.perf_counter()
    compiled = lowered.compile()
    rec["compile_s"] = time.perf_counter() - t1
    rec.update(analyse(lowered, compiled, cfg, cell, n_dev))
    rec["ok"] = True

    os.makedirs(out_dir, exist_ok=True)
    suffix = "" if variant == "base" else f"__{variant}"
    name = f"{arch}__{shape}__{mesh_kind}__{policy}{suffix}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod",
                    choices=["pod", "multipod", "both"])
    ap.add_argument("--policy", default="baseline")
    ap.add_argument("--variant", default="base",
                    help="'_'-composed knobs from: base bf16score xentchunk "
                         "noremat gqaexpand bf16cast gradbf16 | opt")
    ap.add_argument("--out", default="artifacts/dryrun")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    args = ap.parse_args(argv)

    from repro.configs import ARCH_IDS, get_config
    from repro.launch.shapes import cells_for, skipped_cells_for

    if args.list:
        for a in ARCH_IDS:
            cfg = get_config(a)
            print(a, cells_for(cfg),
                  [f"SKIP:{c} ({why[:40]}…)" for c, why in
                   skipped_cells_for(cfg)])
        return 0

    if args.all:
        meshes = (["pod", "multipod"] if args.mesh == "both"
                  else [args.mesh])
        failures = []
        for a in ARCH_IDS:
            for c in cells_for(get_config(a)):
                for mk in meshes:
                    out = os.path.join(
                        args.out, f"{a}__{c}__{mk}__{args.policy}.json")
                    if os.path.exists(out):
                        print(f"[skip cached] {a} {c} {mk}")
                        continue
                    cmd = [sys.executable, "-m", "repro.launch.dryrun",
                           "--arch", a, "--shape", c, "--mesh", mk,
                           "--policy", args.policy, "--out", args.out]
                    print(f"[dryrun] {a} {c} {mk} ...", flush=True)
                    r = subprocess.run(cmd)
                    if r.returncode != 0:
                        failures.append((a, c, mk))
        if failures:
            print("FAILURES:", failures)
            return 1
        print("all cells OK")
        return 0

    assert args.arch and args.shape, "--arch/--shape or --all required"
    meshes = (["pod", "multipod"] if args.mesh == "both" else [args.mesh])
    for mk in meshes:
        rec = run_cell(args.arch, args.shape, mk, args.policy, args.out,
                       remat=not args.no_remat, variant=args.variant)
        print(json.dumps(
            {k: rec[k] for k in ("arch", "shape", "mesh", "variant",
                                 "compile_s", "t_compute_s", "t_memory_s",
                                 "t_memory_flash_s", "t_collective_s",
                                 "dominant", "useful_flops_ratio",
                                 "roofline_fraction")}, indent=1))
        mem = rec.get("memory", {})
        print("memory_analysis:", {k: f"{v/2**30:.2f}GiB"
                                   for k, v in mem.items()
                                   if isinstance(v, float)})
    return 0


if __name__ == "__main__":
    sys.exit(main())
