"""Training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
      --steps 200 --ckpt-every 25 --ckpt-mode async [--restore] \
      [--policy baseline] [--fail-at 120]

On real hardware the same entry point runs the full config on the
production mesh (no --smoke); in this container --smoke selects the
reduced config on the host devices.  --restore resumes from the newest
valid unified snapshot in --run-dir (the CRIUgpu restart path).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-sized)")
    ap.add_argument("--policy", default="baseline")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch-size", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--ckpt-mode", default="async",
                    choices=["sync", "async"])
    ap.add_argument("--incremental", action="store_true")
    ap.add_argument("--keep", type=int, default=3)
    ap.add_argument("--run-dir", default="runs/train")
    ap.add_argument("--restore", action="store_true",
                    help="resume from the newest valid snapshot")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a crash at this step (fault-tolerance demo)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.api import CheckpointOptions
    from repro.configs import get_config, get_smoke_config
    from repro.launch.mesh import make_host_mesh
    from repro.runtime.trainer import TrainConfig, Trainer
    from repro.sharding import get_policy

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    n_dev = len(jax.devices())
    mesh = make_host_mesh(data=n_dev, model=1)
    policy = get_policy(args.policy)
    tcfg = TrainConfig(
        batch_size=args.batch_size, seq_len=args.seq_len, lr=args.lr,
        total_steps=args.steps, ckpt_every=args.ckpt_every,
        ckpt=CheckpointOptions(mode=args.ckpt_mode,
                               incremental=args.incremental,
                               keep=args.keep),
        seed=args.seed,
        compute_dtype=jnp.float32 if args.smoke else jnp.bfloat16)

    trainer = Trainer(cfg, tcfg, mesh, policy, args.run_dir)
    if args.restore:
        step = trainer.restore()
        print(f"[train] restored unified snapshot at step {step}")
    else:
        trainer.initialize()

    try:
        out = trainer.run(args.steps - trainer.step, fail_at=args.fail_at)
    except Exception as e:
        print(f"[train] crashed: {e} — restart with --restore", file=sys.stderr)
        return 1
    print(json.dumps({
        "arch": cfg.name, "steps": out["steps"], "final_loss": out["loss"],
        "wall_s": round(out["wall_s"], 2),
        "snapshots": trainer.session.store.list_steps(),
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
