"""Assigned input-shape cells and abstract input specs for the dry-run.

Per the assignment: 4 shapes per LM arch; ``decode_*``/``long_*`` lower
``serve_step`` (one token against a seq_len cache), ``prefill_32k`` lowers
the prefill, ``train_4k`` lowers the full train step (loss+grads+optimizer).
``long_500k`` requires sub-quadratic attention and runs only for the
SSM/hybrid/SWA archs (mamba2, jamba, h2o-danube); the pure full-attention
archs record the cell as skipped (DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Tuple

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig
from repro.sharding.policy import ShardingPolicy


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str            # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def runs_long_context(cfg: ModelConfig) -> bool:
    return cfg.family in ("ssm", "hybrid") or cfg.sub_quadratic


def cells_for(cfg: ModelConfig) -> List[str]:
    cells = ["train_4k", "prefill_32k", "decode_32k"]
    if runs_long_context(cfg):
        cells.append("long_500k")
    return cells


def skipped_cells_for(cfg: ModelConfig) -> List[Tuple[str, str]]:
    if not runs_long_context(cfg):
        return [("long_500k",
                 "pure full-attention arch: 512k-token decode requires "
                 "sub-quadratic attention (DESIGN.md §4)")]
    return []


# ----------------------------------------------------------------- specs
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, B: int, S: int,
                dtype=jnp.bfloat16) -> Dict[str, Any]:
    """ShapeDtypeStruct stand-ins for one training/prefill batch."""
    batch: Dict[str, Any] = {"tokens": _sds((B, S), jnp.int32)}
    if cfg.vision_stub:
        batch["vision_embeds"] = _sds((B, cfg.num_patches, cfg.d_model),
                                      dtype)
        batch["loss_mask"] = _sds((B, S), jnp.float32)
        batch["positions"] = _sds((3, B, S), jnp.int32)
    if cfg.encoder_layers > 0:
        batch["frames"] = _sds((B, cfg.num_audio_frames, cfg.d_model), dtype)
    return batch


def batch_shardings(cfg: ModelConfig, policy: ShardingPolicy, mesh):
    sh = lambda *ax: policy.for_mesh(mesh).sharding(mesh, *ax)
    out = {"tokens": sh("batch", "seq")}
    if cfg.vision_stub:
        out["vision_embeds"] = sh("batch", None, None)
        out["loss_mask"] = sh("batch", "seq")
        out["positions"] = sh(None, "batch", "seq")
    if cfg.encoder_layers > 0:
        out["frames"] = sh("batch", "frames", None)
    return out


def input_specs(arch_or_cfg, shape: str = "train_4k",
                compute_dtype=jnp.bfloat16) -> Dict[str, Any]:
    """Public helper: abstract inputs for (arch, shape) — no allocation."""
    cfg = arch_or_cfg
    if isinstance(cfg, str):
        from repro.configs import get_config
        cfg = get_config(cfg)
    cell = SHAPES[shape]
    if cell.kind in ("train", "prefill"):
        return batch_specs(cfg, cell.global_batch, cell.seq_len,
                           compute_dtype)
    return {"tokens": _sds((cell.global_batch,), jnp.int32),
            "pos": _sds((), jnp.int32)}
