"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; "pod" is the
DCN axis (data-parallel across slices), "data"/"model" are ICI axes.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — smoke tests."""
    if pod:
        return jax.make_mesh((pod, data, model), ("pod", "data", "model"),
                             axis_types=(AxisType.Auto,) * 3)
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
