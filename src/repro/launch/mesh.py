"""Production mesh construction.

Single pod: (16, 16) = ("data", "model") — 256 chips.
Multi-pod:  (2, 16, 16) = ("pod", "data", "model") — 512 chips; "pod" is the
DCN axis (data-parallel across slices), "data"/"model" are ICI axes.

Functions, not module-level constants: importing this module must never
touch jax device state (the dry-run sets XLA_FLAGS before first jax init).

``make_mesh`` is the single compat shim for JAX versions without
``jax.sharding.AxisType`` / the ``axis_types=`` kwarg (added after 0.4.37):
every mesh in the repo — production, tests, examples, benchmarks — goes
through it so the AxisType probe lives in exactly one place.
"""
from __future__ import annotations

import inspect

import jax


def _axis_type_support():
    """(AxisType-or-None, make_mesh-accepts-axis_types)."""
    try:
        from jax.sharding import AxisType
    except ImportError:
        return None, False
    try:
        ok = "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):  # pragma: no cover - exotic builds
        ok = False
    return AxisType, ok


AXIS_TYPE, HAS_AXIS_TYPES = _axis_type_support()


def make_mesh(shape, axis_names, *, axis_types="auto"):
    """``jax.make_mesh`` that tolerates JAX without ``axis_types``.

    axis_types: "auto" (request AxisType.Auto per axis where supported),
    None (never pass the kwarg), or an explicit tuple forwarded verbatim
    when the running JAX understands it.
    """
    if axis_types is None or not HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axis_names)
    if axis_types == "auto":
        axis_types = (AXIS_TYPE.Auto,) * len(axis_names)
    return jax.make_mesh(shape, axis_names, axis_types=axis_types)


def use_mesh(mesh):
    """Context manager making `mesh` the ambient mesh.

    ``jax.sharding.set_mesh`` where it exists; on older JAX the Mesh
    object itself is the (equivalent) context manager.
    """
    set_mesh = getattr(jax.sharding, "set_mesh", None)
    if set_mesh is not None:
        return set_mesh(mesh)
    return mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1, pod: int = 0):
    """Small mesh over however many (host) devices exist — smoke tests."""
    if pod:
        return make_mesh((pod, data, model), ("pod", "data", "model"))
    return make_mesh((data, model), ("data", "model"))
