"""Serving launcher: batched greedy decode with serving-state snapshots.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
      --batch 4 --prompt-len 16 --tokens 32 [--snapshot-at 16] [--restore]

--snapshot-at N checkpoints the half-finished generation (KV cache +
cursor) after N tokens; --restore resumes it in a fresh process — the
Modal/MemVerge serving cold-start story (paper §6 Real-World Deployments).
"""
from __future__ import annotations

import argparse
import json
import sys

import jax
import jax.numpy as jnp


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--policy", default="baseline")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--run-dir", default="runs/serve")
    ap.add_argument("--snapshot-at", type=int, default=None)
    ap.add_argument("--restore", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import get_config, get_smoke_config
    from repro.data import TokenPipeline
    from repro.launch.mesh import make_host_mesh
    from repro.models.encdec import build_model
    from repro.runtime.server import DecodeServer
    from repro.sharding import get_policy

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh = make_host_mesh(data=len(jax.devices()), model=1)
    policy = get_policy(args.policy)

    srv = DecodeServer(cfg, policy, mesh, args.run_dir,
                       max_seq=args.max_seq,
                       compute_dtype=jnp.float32 if args.smoke
                       else jnp.bfloat16)
    model = build_model(cfg, policy, mesh,
                        compute_dtype=jnp.float32 if args.smoke
                        else jnp.bfloat16, remat=False)
    srv.load(model.init(jax.random.key(args.seed)))

    batch = TokenPipeline(cfg, args.batch, args.prompt_len,
                          seed=args.seed).next()
    srv.start(batch)
    if args.restore:
        pos = srv.restore()
        print(f"[serve] restored mid-generation snapshot at pos {pos}")

    remaining = args.tokens - (srv.pos - args.prompt_len)
    if args.snapshot_at is not None and not args.restore:
        first = min(args.snapshot_at, remaining)
        srv.decode(first)
        path = srv.checkpoint(0)
        print(f"[serve] serving snapshot at pos {srv.pos} -> {path}")
        remaining -= first
    srv.decode(max(remaining, 0))

    out = srv.tokens
    print(json.dumps({
        "arch": cfg.name,
        "generated": int(out.shape[1] - args.prompt_len),
        "tokens_preview": out[0, args.prompt_len:args.prompt_len + 12]
        .tolist(),
        "pos": srv.pos,
    }, indent=1))
    return 0


if __name__ == "__main__":
    sys.exit(main())
