"""Trip-count-aware analysis of compiled (SPMD-partitioned) HLO text.

``compiled.cost_analysis()`` counts a ``while`` body ONCE, but a
scan-over-layers model spends n_layers trips in it — so FLOPs/bytes/
collectives are undercounted by ~the layer count (validated in
tests/test_hlo_analysis.py: an 8-trip scan of 512³ matmuls is reported at
exactly 1/8 by cost_analysis and exactly right here).  This module parses
the HLO text, builds the computation call graph (entry → while bodies →
fusions), extracts loop trip counts (XLA's ``known_trip_count`` backend
config, falling back to the s32 bound in the condition computation), and
attributes every op with its effective execution count.

Optimized HLO references operands by NAME ONLY (``dot(%gte.5, %bc.2)``), so
a per-computation name→shape table is built from the op results/parameters
and used to resolve operand shapes for flop/byte counting.

Counting rules:
  * FLOPs: dot = 2·prod(result)·prod(lhs contracting dims); convolution =
    2·prod(result)·kernel_spatial·in_ch/groups; transcendental ≈ 2/elem,
    elementwise ≈ 1/elem (negligible next to dots).
  * Bytes (HBM traffic model): result + operand buffer sizes for ops at
    fusion *boundaries* (fusion interiors never touch HBM) — the same
    model XLA's own HloCostAnalysis uses.
  * Collectives: ring-algorithm wire bytes per device.
"""
from __future__ import annotations

import dataclasses
import re
from typing import Any, Dict, List, Optional, Tuple

DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "s4": 1,
    "u4": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16, "token": 0,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*(.*?)\s*([a-z][a-z0-9\-]*)\(")
_CALLED = re.compile(
    r"(?:body|condition|to_apply|calls|branch_computations)="
    r"(?:\{)?%?([\w\.\-]+(?:,\s*%?[\w\.\-]+)*)(?:\})?")
_CONST_S32 = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_TRIP_CFG = re.compile(r"known_trip_count.{0,8}?n.{0,4}?(\d+)")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_OPERANDS_RE = re.compile(r"%([\w\.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")


@dataclasses.dataclass
class Op:
    name: str
    kind: str
    line: str
    result: str          # result type string (may be a tuple type)
    comp: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: List[Op]
    is_entry: bool = False
    shapes: Dict[str, str] = dataclasses.field(default_factory=dict)


def _shapes(text: str) -> List[Tuple[str, Tuple[int, ...]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(text):
        if dt in DTYPE_BYTES:
            shape = tuple(int(d) for d in dims.split(",")) if dims else ()
            out.append((dt, shape))
    return out


def _nbytes_of_type(text: str) -> int:
    total = 0
    for dt, shape in _shapes(text):
        n = 1
        for d in shape:
            n *= d
        total += n * DTYPE_BYTES[dt]
    return total


def _args_of(line: str) -> List[str]:
    """Operand names inside the call parens (before attribute list)."""
    start = line.find("(")
    if start < 0:
        return []
    depth = 0
    end = start
    for i in range(start, len(line)):
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
            if depth == 0:
                end = i
                break
    return _OPERANDS_RE.findall(line[start:end + 1])


def parse_module(text: str) -> Dict[str, Computation]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    for line in text.splitlines():
        s = line.strip()
        if s.endswith("{") and ("->" in s or s.startswith("ENTRY")):
            m = re.match(r"^(ENTRY\s+)?%?([\w\.\-]+)", s)
            if m:
                cur = Computation(m.group(2), [], is_entry=bool(m.group(1)))
                comps[cur.name] = cur
            continue
        if s == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OP_RE.match(line)
        if m:
            name, result, kind = m.groups()
            cur.ops.append(Op(name, kind, line.rstrip(), result, cur.name))
            cur.shapes[name] = result
    return comps


def _called_comps(line: str) -> List[str]:
    out = []
    for m in _CALLED.finditer(line):
        for nm in m.group(1).split(","):
            out.append(nm.strip().lstrip("%"))
    return out


def _trip_count(comps: Dict[str, Computation], op: Op,
                cond_name: Optional[str]) -> int:
    # preferred: XLA's own analysis, stamped into backend_config
    m = _TRIP_CFG.search(op.line)
    if m:
        return int(m.group(1))
    cond = comps.get(cond_name) if cond_name else None
    if cond is None:
        return 1
    consts = []
    for o in cond.ops:
        consts += [int(v) for v in _CONST_S32.findall(o.line)]
    return max(consts) if consts else 1


def effective_counts(comps: Dict[str, Computation]
                     ) -> Tuple[Dict[str, float], Dict[str, bool]]:
    """computation name -> execution multiplier; and fusion-interior flag."""
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:                       # fall back: biggest computation
        entry = max(comps.values(), key=lambda c: len(c.ops))
    mult: Dict[str, float] = {}
    interior: Dict[str, bool] = {}

    def visit(comp_name: str, m: float, inside_fusion: bool):
        comp = comps.get(comp_name)
        if comp is None:
            return
        if comp_name in mult and mult[comp_name] >= m and \
                interior.get(comp_name, True) <= inside_fusion:
            return
        mult[comp_name] = max(m, mult.get(comp_name, 0.0))
        interior[comp_name] = inside_fusion and interior.get(comp_name, True)
        for op in comp.ops:
            called = _called_comps(op.line)
            if not called:
                continue
            if op.kind == "while":
                cm = re.search(r"condition=%?([\w\.\-]+)", op.line)
                bm = re.search(r"body=%?([\w\.\-]+)", op.line)
                trips = _trip_count(comps, op, cm.group(1) if cm else None)
                if bm:
                    visit(bm.group(1), m * trips, inside_fusion)
                if cm:
                    visit(cm.group(1), m * trips, inside_fusion)
            elif op.kind == "fusion":
                for c in called:
                    visit(c, m, True)
            else:                            # call / conditional / reduce...
                for c in called:
                    visit(c, m, inside_fusion)

    visit(entry.name, 1.0, False)
    return mult, interior


# ---------------------------------------------------------------- FLOPs
def _resolve(comp: Computation, name: str) -> Optional[str]:
    return comp.shapes.get(name)


def _dot_flops(comp: Computation, op: Op) -> float:
    res = _shapes(op.result)
    if not res:
        return 0.0
    n = 1
    for d in res[0][1]:
        n *= d
    args = _args_of(op.line)
    k = 1
    m = _CONTRACT_RE.search(op.line)
    if args and m is not None:
        lhs_t = _resolve(comp, args[0])
        lhs_shapes = _shapes(lhs_t) if lhs_t else []
        if lhs_shapes and m.group(1):
            lhs = lhs_shapes[0][1]
            for d in m.group(1).split(","):
                i = int(d)
                if i < len(lhs):
                    k *= lhs[i]
    return 2.0 * n * k


def _conv_flops(comp: Computation, op: Op) -> float:
    res = _shapes(op.result)
    if not res:
        return 0.0
    n = 1
    for d in res[0][1]:
        n *= d
    args = _args_of(op.line)
    kelems = 1
    if len(args) >= 2:
        ker_t = _resolve(comp, args[1])
        ker_shapes = _shapes(ker_t) if ker_t else []
        if ker_shapes:
            for d in ker_shapes[0][1]:
                kelems *= d
    out_ch = res[0][1][-1] if res[0][1] else 1
    g = 1
    gm = re.search(r"feature_group_count=(\d+)", op.line)
    if gm:
        g = int(gm.group(1))
    return 2.0 * n * max(1, kelems // max(1, out_ch)) * max(1, out_ch // g) \
        if g > 1 else 2.0 * n * max(1, kelems // max(1, out_ch))


_ELEMWISE_1 = {"add", "subtract", "multiply", "divide", "maximum", "minimum",
               "compare", "select", "and", "or", "xor", "negate", "abs"}
_TRANSCEND = {"exponential", "log", "rsqrt", "sqrt", "tanh", "logistic",
              "power", "sine", "cosine", "expm1", "log1p"}

# no HBM traffic of their own
_NO_TRAFFIC = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "while", "conditional", "call", "after-all",
               "iota"}


def _elem_flops(op: Op) -> float:
    n = 0
    for dt, shape in _shapes(op.result):
        e = 1
        for d in shape:
            e *= d
        n += e
    return float(n)


_SLICING = ("slice", "dynamic-slice", "gather")


def _fusion_param_bytes(comps: Dict[str, Computation], called: str,
                        param_idx: int, full_bytes: int) -> int:
    """Traffic attributable to fusion operand `param_idx`.

    If every in-fusion user of the parameter is a slicing op, the fusion
    only reads the slices (XLA emits the loads per-slice) — count those;
    otherwise the whole operand streams in."""
    comp = comps.get(called)
    if comp is None:
        return full_bytes
    pname = None
    for o in comp.ops:
        if o.kind == "parameter" and f"parameter({param_idx})" in o.line:
            pname = o.name
            break
    if pname is None:
        return full_bytes
    users = [o for o in comp.ops
             if pname in _args_of(o.line) and o.kind != "parameter"]
    if users and all(u.kind in _SLICING for u in users):
        return sum(_nbytes_of_type(u.result) for u in users)
    return full_bytes


def _op_bytes(comp: Computation, op: Op,
              comps: Optional[Dict[str, Computation]] = None) -> int:
    """HBM traffic of one boundary op.

    Slicing/gather/scatter ops touch only the slice/update, not the full
    operand (XLA's HloCostAnalysis models them the same way) — without
    this, a scan-over-layers loop that dynamic-slices its stacked params
    appears to re-read *every* layer's weights *every* iteration."""
    kind = op.kind
    res = _nbytes_of_type(op.result)
    if kind in ("slice", "dynamic-slice", "gather"):
        return 2 * res                       # read slice + write result
    if kind in ("dynamic-update-slice",):
        args = _args_of(op.line)
        upd = _resolve(comp, args[1]) if len(args) > 1 else None
        u = _nbytes_of_type(upd) if upd else res
        return 2 * u                         # read update + write in place
    if kind in ("scatter",):
        args = _args_of(op.line)
        upd = _resolve(comp, args[2]) if len(args) > 2 else None
        u = _nbytes_of_type(upd) if upd else res
        return 3 * u                         # read target+update, write
    if kind == "broadcast":
        args = _args_of(op.line)
        src = _resolve(comp, args[0]) if args else None
        return res + (_nbytes_of_type(src) if src else 0)
    if kind == "fusion" and comps is not None:
        called = None
        cm = re.search(r"calls=%?([\w\.\-]+)", op.line)
        if cm:
            called = cm.group(1)
        ccomp = comps.get(called) if called else None
        # fusion containing a dynamic-update-slice over a buffer the size
        # of the fusion result: on TPU these alias in place (traffic =
        # update read + update write); the CPU backend sometimes emits a
        # whole-buffer convert round-trip around the DUS (a host-backend
        # artifact the TPU scheduler provably cannot afford) — model the
        # TPU behaviour.
        dus_update = None
        total = res
        if ccomp and ccomp.ops:
            for o in ccomp.ops:
                if o.kind == "dynamic-update-slice" and \
                        _nbytes_of_type(o.result) * 2 >= res:
                    dargs = _args_of(o.line)
                    upd = (_resolve(ccomp, dargs[1])
                           if len(dargs) > 1 else None)
                    if upd is not None:
                        dus_update = _nbytes_of_type(upd)
                    break
        if dus_update is not None:
            total = 2 * dus_update       # in-place: read + write the slice
            return total
        for i, a in enumerate(_args_of(op.line)):
            t = _resolve(comp, a)
            if not t:
                continue
            fb = _nbytes_of_type(t)
            if called:
                fb = _fusion_param_bytes(comps, called, i, fb)
            total += fb
        return total
    total = res
    for a in _args_of(op.line):
        t = _resolve(comp, a)
        if t:
            total += _nbytes_of_type(t)
    return total


def _score_bytes_of(comp: Computation, op: Op, cutoff: int,
                    seq_len: Optional[int]) -> int:
    """Bytes of this op's result+operand tensors that are attention
    score/prob blocks.  With ``seq_len`` known (the dry-run passes the
    cell's key length): trailing dim == seq_len and second-to-last >= 256
    — catches both the square (S×S) train blocks and the rectangular
    (q_chunk × S) chunked-prefill blocks while excluding the remat stash
    (…, S, d_model).  Fallback (no seq_len): square trailing dims >=
    cutoff.  A flash (Pallas) attention kernel keeps exactly these in
    VMEM; subtracting them models the kernel-substituted memory term."""
    def match(shape) -> bool:
        if len(shape) < 2:
            return False
        if seq_len is not None:
            return shape[-1] == seq_len and shape[-2] >= 256
        return shape[-1] == shape[-2] and shape[-1] >= cutoff

    def sb(type_str: Optional[str]) -> int:
        if not type_str:
            return 0
        total = 0
        for dt, shape in _shapes(type_str):
            if match(shape):
                n = 1
                for d in shape:
                    n *= d
                total += n * DTYPE_BYTES[dt]
        return total

    total = sb(op.result)
    for a in _args_of(op.line):
        total += sb(_resolve(comp, a))
    return total


def analyze_hlo(text: str, n_devices: int = 1,
                score_cutoff: int = 1024,
                seq_len: Optional[int] = None) -> Dict[str, Any]:
    comps = parse_module(text)
    mult, interior = effective_counts(comps)

    flops = 0.0
    bytes_hbm = 0.0
    score_bytes = 0.0
    flop_by_kind: Dict[str, float] = {}
    bytes_by_kind: Dict[str, float] = {}
    coll_tops: List[Tuple[float, str]] = []
    byte_tops: List[Tuple[float, str]] = []
    coll = {op: {"count": 0.0, "bytes": 0.0, "wire_bytes": 0.0}
            for op in COLLECTIVES}

    for comp in comps.values():
        m = mult.get(comp.name, 0.0)
        if m == 0.0:
            continue
        inside = interior.get(comp.name, False)
        for op in comp.ops:
            kind = op.kind
            f = 0.0
            if kind == "dot":
                f = _dot_flops(comp, op)
            elif kind == "convolution":
                f = _conv_flops(comp, op)
            elif kind in _TRANSCEND:
                f = 2.0 * _elem_flops(op)
            elif kind in _ELEMWISE_1:
                f = _elem_flops(op)
            if f:
                flops += m * f
                key = kind if kind in ("dot", "convolution") else "elemwise"
                flop_by_kind[key] = flop_by_kind.get(key, 0.0) + m * f

            if not inside and kind not in _NO_TRAFFIC:
                b = _op_bytes(comp, op, comps)
                bytes_hbm += m * b
                bytes_by_kind[kind] = bytes_by_kind.get(kind, 0.0) + m * b
                byte_tops.append((m * b, f"x{m:.0f} " + op.line.strip()[:110]))
                score_bytes += m * min(b, _score_bytes_of(
                    comp, op, score_cutoff, seq_len))

            base = kind[:-6] if kind.endswith("-start") else kind
            if base in COLLECTIVES and not kind.endswith("-done"):
                nbytes = _nbytes_of_type(op.result)
                if kind.endswith("-start"):
                    nbytes //= 2        # start result is (operand, result)
                g = n_devices
                mg = _GROUPS_RE.search(op.line)
                if mg:
                    g = len(mg.group(1).split(","))
                else:
                    mi = _GROUPS_IOTA_RE.search(op.line)
                    if mi:
                        g = int(mi.group(2))
                g = max(g, 1)
                if base == "all-gather":
                    wire = nbytes * (g - 1) / g
                elif base == "all-reduce":
                    wire = 2.0 * nbytes * (g - 1) / g
                elif base == "reduce-scatter":
                    wire = nbytes * (g - 1)
                elif base == "all-to-all":
                    wire = nbytes * (g - 1) / g
                else:
                    wire = float(nbytes)
                coll[base]["count"] += m
                coll[base]["bytes"] += m * nbytes
                coll[base]["wire_bytes"] += m * wire
                coll_tops.append((m * wire,
                                  f"x{m:.0f} " + op.line.strip()[:110]))

    total_wire = sum(v["wire_bytes"] for v in coll.values())
    coll_tops.sort(key=lambda t: -t[0])
    byte_tops.sort(key=lambda t: -t[0])
    return {
        "flops": flops,
        "bytes": bytes_hbm,
        "score_bytes": score_bytes,      # attention-score HBM traffic
        "flops_by_kind": flop_by_kind,
        "bytes_by_kind": bytes_by_kind,
        "top_traffic": byte_tops[:10],
        "top_collectives": coll_tops[:10],
        "collectives": coll,
        "collective_wire_bytes": total_wire,
        "collective_count": sum(v["count"] for v in coll.values()),
        "n_computations": len(comps),
    }


def top_buffers(text: str, k: int = 12) -> List[Tuple[float, str]]:
    """Largest single result buffers in the module (MiB, op line prefix) —
    the §Perf memory-debugging view."""
    comps = parse_module(text)
    mult, _ = effective_counts(comps)
    out = []
    for comp in comps.values():
        if mult.get(comp.name, 0.0) == 0.0:
            continue
        for op in comp.ops:
            if op.kind in ("parameter", "tuple", "get-tuple-element"):
                continue
            b = _nbytes_of_type(op.result)
            if b > 0:
                out.append((b / 2**20, op.line.strip()[:140]))
    out.sort(key=lambda t: -t[0])
    return out[:k]
