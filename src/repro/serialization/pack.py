"""Single-file pack format for snapshot payloads.

Layout:  [8-byte magic][8-byte LE index length][msgpack index][blob...]
The index maps entry name -> {offset, nbytes, crc32, dtype, shape, meta,
codec}.  Blobs are raw little-endian array bytes, optionally zstd-compressed
(per-entry).  Entries are append-only; the index is written last, but the
header slot for its length is reserved up front so readers can locate it.

This is deliberately self-contained (no tensorstore/orbax dependency): the
paper's mechanism needs byte-level control for the incremental/differential
mode (per-entry CRCs double as content hashes) and per-host shard dumps.
"""
from __future__ import annotations

import io
import os
import struct
from typing import Any, Dict, Iterator, Optional, Tuple

import msgpack
import numpy as np

try:
    import zstandard as zstd
    _ZSTD = True
except Exception:                                    # pragma: no cover
    _ZSTD = False
import zlib as _zlib                                 # always-available fallback

from repro.serialization.integrity import crc32


def _compress_blob(raw: bytes, level: int) -> Tuple[bytes, str]:
    """Best-available codec: zstd if installed, else zlib."""
    if _ZSTD:
        return zstd.ZstdCompressor(level=level).compress(raw), "zstd"
    return _zlib.compress(raw, min(level * 2, 9)), "zlib"


def _decompress_blob(raw: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstd.ZstdDecompressor().decompress(raw)
    if codec == "zlib":
        return _zlib.decompress(raw)
    return raw

MAGIC = b"RPRPACK1"


def dtype_to_str(dt) -> str:
    """Name-based encoding so ml_dtypes extension types (bfloat16, fp8)
    round-trip; their numpy ``.str`` is an opaque void type."""
    dt = np.dtype(dt)
    return dt.name if dt.kind == "V" else dt.str


def dtype_from_str(s: str) -> np.dtype:
    try:
        return np.dtype(s)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, s))


class PackWriter:
    def __init__(self, path: str, compress: bool = False, level: int = 3):
        self.path = path
        self.tmp = path + ".tmp"
        self._f = open(self.tmp, "wb")
        self._f.write(MAGIC)
        self._f.write(struct.pack("<Q", 0))          # index placeholder
        self._index: Dict[str, Dict[str, Any]] = {}
        self._compress = compress
        self._level = level
        self._closed = False

    def add(self, name: str, array: np.ndarray,
            meta: Optional[Dict[str, Any]] = None) -> None:
        assert not self._closed
        arr = np.asarray(array, order="C")   # ascontiguousarray 1-d-ifies 0-d
        raw = arr.tobytes()
        codec = "raw"
        if self._compress:
            comp, cname = _compress_blob(raw, self._level)
            if len(comp) < len(raw) * 0.9:
                raw, codec = comp, cname
        off = self._f.tell()
        self._f.write(raw)
        self._index[name] = {
            "offset": off, "nbytes": len(raw), "crc32": crc32(raw),
            "dtype": dtype_to_str(arr.dtype), "shape": list(arr.shape),
            "codec": codec, "meta": meta or {},
        }

    def add_bytes(self, name: str, raw: bytes,
                  meta: Optional[Dict[str, Any]] = None) -> None:
        assert not self._closed
        off = self._f.tell()
        self._f.write(raw)
        self._index[name] = {
            "offset": off, "nbytes": len(raw), "crc32": crc32(raw),
            "dtype": None, "shape": None, "codec": "raw", "meta": meta or {},
        }

    def close(self) -> Dict[str, Any]:
        assert not self._closed
        idx = msgpack.packb(self._index, use_bin_type=True)
        idx_off = self._f.tell()
        self._f.write(idx)
        self._f.seek(len(MAGIC))
        self._f.write(struct.pack("<Q", idx_off))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.rename(self.tmp, self.path)
        self._closed = True
        return self._index

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if not self._closed:
            if exc[0] is None:
                self.close()
            else:                                    # failed write: no commit
                self._f.close()
                try:
                    os.remove(self.tmp)
                except OSError:
                    pass


class PackReader:
    def __init__(self, path: str, verify: bool = True):
        self.path = path
        self._f = open(path, "rb")
        magic = self._f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (idx_off,) = struct.unpack("<Q", self._f.read(8))
        self._f.seek(idx_off)
        self.index: Dict[str, Dict[str, Any]] = msgpack.unpackb(
            self._f.read(), raw=False)
        self._verify = verify

    def names(self):
        return list(self.index)

    def entry(self, name: str) -> Dict[str, Any]:
        return self.index[name]

    def read_bytes(self, name: str) -> bytes:
        e = self.index[name]
        self._f.seek(e["offset"])
        raw = self._f.read(e["nbytes"])
        if self._verify and crc32(raw) != e["crc32"]:
            raise IOError(f"{self.path}:{name}: CRC mismatch (torn write?)")
        return _decompress_blob(raw, e["codec"])

    def read_array(self, name: str) -> np.ndarray:
        e = self.index[name]
        raw = self.read_bytes(name)
        return np.frombuffer(raw, dtype=dtype_from_str(e["dtype"])
                             ).reshape(e["shape"]).copy()

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
