"""Pack formats for snapshot payloads.

v1 — single file:  [8-byte magic "RPRPACK1"][8-byte LE index length]
[blob...][msgpack index].  The index maps entry name -> {offset, nbytes,
crc32, dtype, shape, meta, codec}.  Blobs are raw little-endian array
bytes, optionally compressed per-entry.  Written by :class:`PackWriter`,
read by :class:`PackReader`.

v2 — chunked + striped (the pipelined data plane):  an entry's raw bytes
are split into fixed-size chunks; each chunk carries its own CRC and codec
and is appended to one of N stripe files (``<base>.0 .. <base>.N-1``,
round-robin).  Stripe 0's footer holds the full logical index::

    {"format": 2, "stripes": N, "chunk_bytes": C,
     "entries": {name: {dtype, shape, meta, raw_nbytes, crc32,
                        chunks: [{stripe, offset, nbytes, raw_nbytes,
                                  crc32, raw_crc32, codec, ref?}, ...]}}}

Per-chunk ``raw_crc32`` doubles as a content hash: an incremental child
whose chunk matches the parent's records a ``ref`` (the parent pack's
location, relative to the snapshots root) instead of rewriting the bytes —
finer-grained dedup than v1's whole-entry reuse.  :class:`PackWriterV2`
runs a bounded pipeline (caller thread chunks + hashes -> compress/CRC
worker pool -> one appender thread per stripe), so compression overlaps
file I/O; :class:`PackReaderV2` reads chunks in parallel and places them
directly into one preallocated buffer (no per-entry reassembly copies).

:func:`open_pack` sniffs the on-disk layout and returns the right reader,
so v1 images written by older code keep restoring byte-identically.

This is deliberately self-contained (no tensorstore/orbax dependency): the
paper's mechanism needs byte-level control for the incremental/differential
mode (chunk CRCs double as content hashes) and per-host shard dumps.
"""
from __future__ import annotations

import os
import queue
import struct
import threading
import time
from typing import Any, Dict, List, Optional, Tuple, Union

import msgpack
import numpy as np

from repro.chaos import hooks as chaos_hooks
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

try:
    import zstandard as zstd
    _ZSTD = True
except Exception:                                    # pragma: no cover
    _ZSTD = False
import zlib as _zlib                                 # always-available fallback

from repro.serialization.integrity import crc32


def _compress_blob(raw, level: int) -> Tuple[bytes, str]:
    """Best-available codec: zstd if installed, else zlib."""
    if _ZSTD:
        return zstd.ZstdCompressor(level=level).compress(raw), "zstd"
    return _zlib.compress(raw, min(level * 2, 9)), "zlib"


def _compress_chunk(raw, level: int) -> Tuple[bytes, str]:
    """Chunk codec for the pipelined plane.  Unlike :func:`_compress_blob`
    (which doubles the level for zlib — the v1 ratio-oriented tuning),
    the level maps 1:1: the pipeline optimizes wall-clock, and e.g.
    zlib-4 compresses ~4x faster than v1's effective zlib-6 at a few
    points worse ratio."""
    if _ZSTD:
        return zstd.ZstdCompressor(level=level).compress(raw), "zstd"
    return _zlib.compress(raw, min(level, 9)), "zlib"


def _decompress_blob(raw: bytes, codec: str) -> bytes:
    if codec == "zstd":
        return zstd.ZstdDecompressor().decompress(raw)
    if codec == "zlib":
        return _zlib.decompress(raw)
    return raw

MAGIC = b"RPRPACK1"
MAGIC2 = b"RPRPACK2"
DEFAULT_CHUNK_BYTES = 4 << 20


def dtype_to_str(dt) -> str:
    """Name-based encoding so ml_dtypes extension types (bfloat16, fp8)
    round-trip; their numpy ``.str`` is an opaque void type."""
    dt = np.dtype(dt)
    return dt.name if dt.kind == "V" else dt.str


def dtype_from_str(s: str) -> np.dtype:
    try:
        return np.dtype(s)
    except TypeError:
        import ml_dtypes
        return np.dtype(getattr(ml_dtypes, s))


def stripe_path(base: str, stripe: int) -> str:
    return f"{base}.{stripe}"


def pack_exists(base: str) -> bool:
    return os.path.exists(base) or os.path.exists(stripe_path(base, 0))


def _remove_stale_layout(base: str, stripes: int) -> None:
    """After committing a pack, remove files of the *other* layout (and
    surplus stripes) left by an earlier write of the same step — the
    existence-sniffing reader must never find a stale sibling.
    `stripes=0` means a v1 single-file pack was just committed."""
    if stripes > 0:
        try:
            os.remove(base)                          # stale v1 single file
        except OSError:
            pass
    k = max(stripes, 0)
    while True:
        try:
            os.remove(stripe_path(base, k))          # stale/surplus stripes
        except OSError:
            return
        k += 1


def pack_files(base: str) -> List[str]:
    """Physical files of the pack at `base` (v1: one file; v2: stripes)."""
    if os.path.exists(base):
        return [base]
    out = []
    k = 0
    while os.path.exists(stripe_path(base, k)):
        out.append(stripe_path(base, k))
        k += 1
    if not out:
        raise FileNotFoundError(f"no pack at {base} (nor {base}.0)")
    return out


class PackWriter:
    """v1 single-file serial writer (kept for the serial-compat mode and
    byte-identical back-compat with images written by older code)."""

    def __init__(self, path: str, compress: bool = False, level: int = 3):
        self.path = path
        self.tmp = path + ".tmp"
        self._f = open(self.tmp, "wb")
        self._f.write(MAGIC)
        self._f.write(struct.pack("<Q", 0))          # index placeholder
        self._index: Dict[str, Dict[str, Any]] = {}
        self._compress = compress
        self._level = level
        self._closed = False

    def add(self, name: str, array: np.ndarray,
            meta: Optional[Dict[str, Any]] = None, parent=None) -> None:
        assert not self._closed
        arr = np.asarray(array, order="C")   # ascontiguousarray 1-d-ifies 0-d
        raw = arr.tobytes()
        codec = "raw"
        if self._compress:
            comp, cname = _compress_blob(raw, self._level)
            if len(comp) < len(raw) * 0.9:
                raw, codec = comp, cname
        off = self._f.tell()
        self._f.write(raw)
        self._index[name] = {
            "offset": off, "nbytes": len(raw), "crc32": crc32(raw),
            "dtype": dtype_to_str(arr.dtype), "shape": list(arr.shape),
            "codec": codec, "meta": meta or {},
        }

    def add_bytes(self, name: str, raw: bytes,
                  meta: Optional[Dict[str, Any]] = None) -> None:
        assert not self._closed
        off = self._f.tell()
        self._f.write(raw)
        self._index[name] = {
            "offset": off, "nbytes": len(raw), "crc32": crc32(raw),
            "dtype": None, "shape": None, "codec": "raw", "meta": meta or {},
        }

    def entry_crc(self, name: str) -> int:
        return self._index[name]["crc32"]

    def close(self) -> Dict[str, Any]:
        assert not self._closed
        idx = msgpack.packb(self._index, use_bin_type=True)
        idx_off = self._f.tell()
        self._f.write(idx)
        self._f.seek(len(MAGIC))
        self._f.write(struct.pack("<Q", idx_off))
        self._f.flush()
        os.fsync(self._f.fileno())
        self._f.close()
        os.rename(self.tmp, self.path)
        _remove_stale_layout(self.path, 0)
        self._closed = True
        return self._index

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if not self._closed:
            if exc[0] is None:
                self.close()
            else:                                    # failed write: no commit
                self._f.close()
                try:
                    os.remove(self.tmp)
                except OSError:
                    pass


class PackReader:
    """v1 single-file reader (one OS file handle; not thread-safe)."""

    format = 1

    def __init__(self, path: str, verify: bool = True):
        self.path = path
        self._f = open(path, "rb")
        magic = self._f.read(8)
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic!r}")
        (idx_off,) = struct.unpack("<Q", self._f.read(8))
        self._f.seek(idx_off)
        self.index: Dict[str, Dict[str, Any]] = msgpack.unpackb(
            self._f.read(), raw=False)
        self._verify = verify

    def names(self):
        return list(self.index)

    def entry(self, name: str) -> Dict[str, Any]:
        return self.index[name]

    def entry_nbytes(self, name: str) -> int:
        """Stored payload size (v1 has no raw/stored split in the index)."""
        return int(self.index[name]["nbytes"])

    def read_bytes(self, name: str) -> bytes:
        e = self.index[name]
        self._f.seek(e["offset"])
        raw = self._f.read(e["nbytes"])
        if self._verify and crc32(raw) != e["crc32"]:
            raise IOError(f"{self.path}:{name}: CRC mismatch (torn write?)")
        return _decompress_blob(raw, e["codec"])

    def read_array(self, name: str) -> np.ndarray:
        e = self.index[name]
        raw = self.read_bytes(name)
        return np.frombuffer(raw, dtype=dtype_from_str(e["dtype"])
                             ).reshape(e["shape"]).copy()

    def io_stats(self) -> Dict[str, float]:
        return {}

    def close(self):
        self._f.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


# ------------------------------------------------------------------ v2
_DONE = object()          # queue sentinel


class PackWriterV2:
    """Chunked, striped, pipelined pack writer.

    The caller thread (``add``/``add_bytes``) slices entries into chunks,
    CRCs the raw bytes (the content hash used for incremental chunk
    dedup), and feeds a bounded queue.  `workers` compress+CRC threads
    drain it and route finished chunks to per-stripe appender threads, so
    compression runs concurrently with file writes and with the caller's
    own capture loop.  ``close()`` drains the pipeline, writes the logical
    index into stripe 0's footer, fsyncs, and atomically renames every
    stripe into place (crash mid-write leaves only ``*.tmp`` litter that a
    later snapshot of the same step overwrites).
    """

    def __init__(self, base_path: str, compress: bool = False,
                 level: int = 4, chunk_bytes: int = DEFAULT_CHUNK_BYTES,
                 stripes: int = 2, workers: int = 2):
        if chunk_bytes < 1:
            raise ValueError(f"chunk_bytes must be >= 1, got {chunk_bytes}")
        if stripes < 1:
            raise ValueError(f"stripes must be >= 1, got {stripes}")
        self.base = base_path
        self.chunk_bytes = chunk_bytes
        self.stripes = stripes
        self._compress = compress
        self._level = level
        self._entries: Dict[str, Dict[str, Any]] = {}
        self._closed = False
        self._errors: List[BaseException] = []
        self._rr = 0                                  # round-robin stripe
        self.reused_chunk_bytes = 0
        self.ref_locs: set = set()
        self.compress_s = 0.0
        self.io_s = 0.0
        self.stripe_bytes = [0] * stripes
        self._stats_lock = threading.Lock()
        # per-entry raw chunk CRCs, kept out of the records (the footer
        # serializes _entries verbatim); the concurrent-capture validate
        # pass re-hashes live bytes against these
        self._raw_crcs: Dict[str, List[int]] = {}
        self.superseded_bytes = 0        # dead bytes left by replace()
        self._outstanding = 0            # chunks still in the pipeline
        self._flush_cv = threading.Condition()

        workers = max(1, workers)
        self._comp_q: "queue.Queue" = queue.Queue(maxsize=workers * 4)
        self._stripe_qs: List["queue.Queue"] = [
            queue.Queue(maxsize=4) for _ in range(stripes)]
        self._files = [open(stripe_path(base_path, k) + ".tmp", "wb")
                       for k in range(stripes)]
        for f in self._files:
            f.write(MAGIC2)
            f.write(struct.pack("<Q", 0))            # index placeholder
        # named threads: span/thread attribution in the obs plane (and
        # legible py-spy dumps) — "which stripe appender is slow" needs
        # a stable identity per worker; they inherit the constructing
        # thread's span context (job attribution) for detail spans
        self._obs_ctx = obs_trace.current_context()
        self._comp_threads = [
            threading.Thread(target=self._compress_loop, daemon=True,
                             name=f"repro-pack-compress-{i}")
            for i in range(workers)]
        self._stripe_threads = [
            threading.Thread(target=self._stripe_loop, args=(k,),
                             daemon=True, name=f"repro-pack-stripe-{k}")
            for k in range(stripes)]
        for t in self._comp_threads + self._stripe_threads:
            t.start()

    # ----------------------------------------------------------- pipeline
    def _put(self, q: "queue.Queue", item) -> None:
        """Bounded put that aborts instead of deadlocking if a downstream
        thread has died with an error."""
        while True:
            if self._errors:
                raise self._errors[0]
            try:
                q.put(item, timeout=0.1)
                return
            except queue.Full:
                continue

    def _compress_one(self, part) -> Tuple[Any, str]:
        data, codec = part, "raw"
        if self._compress:
            t0 = time.perf_counter()
            comp, cname = _compress_chunk(part, self._level)
            if len(comp) < len(part) * 0.9:
                data, codec = comp, cname
            with self._stats_lock:
                self.compress_s += time.perf_counter() - t0
        return data, codec

    def _compress_loop(self) -> None:
        try:
            with obs_trace.context(**self._obs_ctx):
                self._compress_loop_inner()
        except BaseException as e:                     # pragma: no cover
            self._errors.append(e)

    def _compress_loop_inner(self) -> None:
        while True:
            item = self._comp_q.get()
            if item is _DONE:
                return
            rec, j, part, stripe, rcrc = item
            if self._errors:
                self._chunk_done()
                continue                               # drain without work
            # per-chunk spans only in detail mode: this loop is the
            # hot path the disabled-overhead gate protects, so the
            # guard is one module-attribute load
            tr = obs_trace.TRACER
            if tr is not None and tr.detail:
                with tr.begin("pack.compress",
                              {"chunk": j, "nbytes": len(part)}):
                    data, codec = self._compress_one(part)
            else:
                data, codec = self._compress_one(part)
            scrc = crc32(data)
            self._put(self._stripe_qs[stripe],
                      (rec, j, data, len(part), scrc, rcrc, codec))

    def _append_one(self, f, k: int, rec, j: int, data, raw_n: int,
                    scrc: int, rcrc: int, codec: str) -> None:
        t0 = time.perf_counter()
        off = f.tell()
        f.write(data)
        if chaos_hooks.INJECTOR is not None:
            # chaos: torn-write site — a handler may corrupt the
            # bytes just written (it must restore the file
            # position); the stored CRC already in flight then no
            # longer matches what is on disk
            chaos_hooks.fire("pack.chunk", file=f, offset=off,
                             data=data, dtype=rec["dtype"],
                             stripe=k, base=self.base)
        with self._stats_lock:
            self.io_s += time.perf_counter() - t0
            self.stripe_bytes[k] += len(data)
        # each chunk slot is written exactly once
        rec["chunks"][j] = {
            "stripe": k, "offset": off, "nbytes": len(data),
            "raw_nbytes": raw_n, "crc32": scrc, "raw_crc32": rcrc,
            "codec": codec,
        }

    def _stripe_loop(self, k: int) -> None:
        try:
            with obs_trace.context(**self._obs_ctx):
                self._stripe_loop_inner(k)
        except BaseException as e:                     # pragma: no cover
            self._errors.append(e)

    def _stripe_loop_inner(self, k: int) -> None:
        f = self._files[k]
        while True:
            item = self._stripe_qs[k].get()
            if item is _DONE:
                return
            rec, j, data, raw_n, scrc, rcrc, codec = item
            if self._errors:
                self._chunk_done()
                continue
            tr = obs_trace.TRACER
            if tr is not None and tr.detail:
                with tr.begin("pack.append",
                              {"stripe": k, "chunk": j,
                               "nbytes": len(data)}):
                    self._append_one(f, k, rec, j, data, raw_n,
                                     scrc, rcrc, codec)
            else:
                self._append_one(f, k, rec, j, data, raw_n,
                                 scrc, rcrc, codec)
            obs_metrics.counter_add("pack.chunks")
            self._chunk_done()

    def _chunk_done(self) -> None:
        with self._flush_cv:
            self._outstanding -= 1
            self._flush_cv.notify_all()

    def flush(self, timeout: Optional[float] = None) -> None:
        """Block until every enqueued chunk has landed in its stripe file
        (records fully populated) without closing the pack — the
        concurrent-capture validate pass needs the speculated chunk
        metadata while the stripe set stays open for re-capture."""
        obs_metrics.gauge_set("pack.queue_depth", self._comp_q.qsize())
        with obs_trace.span("pack.flush",
                            outstanding=self._outstanding):
            self._flush(timeout)

    def _flush(self, timeout: Optional[float] = None) -> None:
        deadline = (time.perf_counter() + timeout) if timeout else None
        with self._flush_cv:
            while self._outstanding > 0 and not self._errors:
                if deadline is not None and time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"{self.base}: flush timed out with "
                        f"{self._outstanding} chunk(s) still in flight")
                self._flush_cv.wait(timeout=0.1)
        if self._errors:
            raise self._errors[0]

    # ---------------------------------------------------------------- add
    def _add_blob(self, name: str, raw, dtype: Optional[str],
                  shape: Optional[list], meta: Optional[Dict[str, Any]],
                  parent: Optional[Tuple[Dict[str, Any], str]],
                  chunk_crcs: Optional[List[int]] = None) -> None:
        assert not self._closed
        if self._errors:
            raise self._errors[0]
        mv = memoryview(raw)
        n = len(mv)
        C = self.chunk_bytes
        nchunks = (n + C - 1) // C
        rec: Dict[str, Any] = {
            "dtype": dtype, "shape": shape, "meta": meta or {},
            "raw_nbytes": n, "crc32": 0, "chunks": [None] * nchunks,
        }
        self._entries[name] = rec
        # parent = (entry record of the same name in the parent image,
        #           parent pack location "step_XXXXXXXX/hostYYYY.pack");
        # only offered when the parent is v2 with the same chunk size.
        prev_chunks = parent[0]["chunks"] if parent else []
        running = 0
        raw_crcs: List[int] = []
        for j in range(nchunks):
            part = mv[j * C:(j + 1) * C]
            rcrc = chunk_crcs[j] if chunk_crcs else crc32(part)
            raw_crcs.append(rcrc)
            running = crc32(part, running)
            p = prev_chunks[j] if j < len(prev_chunks) else None
            if (p is not None and p.get("raw_crc32") == rcrc
                    and p["raw_nbytes"] == len(part)):
                c = dict(p)                           # chunk-level dedup
                c.setdefault("ref", parent[1])
                rec["chunks"][j] = c
                self.reused_chunk_bytes += len(part)
                self.ref_locs.add(c["ref"])
            else:
                stripe = self._rr
                self._rr = (self._rr + 1) % self.stripes
                with self._flush_cv:
                    self._outstanding += 1
                self._put(self._comp_q, (rec, j, part, stripe, rcrc))
        rec["crc32"] = running            # == crc32 of the full raw bytes
        self._raw_crcs[name] = raw_crcs

    def add(self, name: str, array: np.ndarray,
            meta: Optional[Dict[str, Any]] = None,
            parent: Optional[Tuple[Dict[str, Any], str]] = None,
            raw_bytes: Optional[bytes] = None,
            chunk_crcs: Optional[List[int]] = None) -> None:
        """`raw_bytes`/`chunk_crcs` let a caller that already serialized
        and hashed the array (the snapshot writer's dedup decision) skip
        the second tobytes()/CRC pass."""
        arr = np.asarray(array, order="C")
        self._add_blob(name, raw_bytes if raw_bytes is not None
                       else arr.tobytes(), dtype_to_str(arr.dtype),
                       list(arr.shape), meta, parent, chunk_crcs)

    def add_bytes(self, name: str, raw: bytes,
                  meta: Optional[Dict[str, Any]] = None) -> None:
        self._add_blob(name, raw, None, None, meta, None)

    def entry_crc(self, name: str) -> int:
        return self._entries[name]["crc32"]

    def raw_crcs(self, name: str) -> List[int]:
        """Per-chunk raw-byte CRCs of an entry as speculated — the
        content hashes the validate pass compares live bytes against."""
        return list(self._raw_crcs[name])

    def replace(self, name: str, array: np.ndarray,
                meta: Optional[Dict[str, Any]] = None,
                own_loc: Optional[str] = None,
                raw_bytes: Optional[bytes] = None,
                chunk_crcs: Optional[List[int]] = None) -> None:
        """Re-capture an entry into the open stripe set (concurrent
        capture's patch phase).  The old record becomes the dedup parent
        of the new one, so chunks the mutation did not touch stay as
        references to the bytes already on disk — only invalidated
        chunks are appended.  ``own_loc`` is this pack's own location
        string ("step_XXXXXXXX/hostYYYY.pack"): self-references resolve
        through the reader's normal ref path.  Call ``flush()`` first so
        the old record's chunk slots are fully populated.

        The superseded chunks stay in the stripe files as dead bytes
        (tracked in ``superseded_bytes``); an append-only patch beats
        rewriting stripes during the final pause.
        """
        assert not self._closed
        old = self._entries.get(name)
        if old is None:
            raise KeyError(f"replace of unknown entry {name!r}")
        if any(c is None for c in old["chunks"]):
            raise RuntimeError(
                f"replace({name!r}) before flush(): speculated chunks "
                f"still in flight")
        arr = np.asarray(array, order="C")
        rawb = raw_bytes if raw_bytes is not None else arr.tobytes()
        if chunk_crcs is None:
            mv = memoryview(rawb)
            C = self.chunk_bytes
            chunk_crcs = [crc32(mv[o:o + C])
                          for o in range(0, len(rawb), C)]
        # dead bytes = chunks written into this pack whose content no
        # longer matches (self-referenced unchanged chunks stay live)
        with self._stats_lock:
            self.superseded_bytes += sum(
                c["nbytes"] for j, c in enumerate(old["chunks"])
                if "ref" not in c
                and (j >= len(chunk_crcs)
                     or chunk_crcs[j] != c.get("raw_crc32")
                     or c["raw_nbytes"] != min(
                         self.chunk_bytes, len(rawb) - j * self.chunk_bytes)))
        parent = (old, own_loc) if own_loc else None
        self._add_blob(name, rawb, dtype_to_str(arr.dtype),
                       list(arr.shape), meta, parent, chunk_crcs)

    # -------------------------------------------------------------- close
    def _post_done(self, q: "queue.Queue") -> None:
        """Deliver a sentinel even if the consumer died with the queue
        full (an errored worker stops draining; blocking put() would
        deadlock close()/abort() — exactly when they matter most)."""
        while True:
            try:
                q.put(_DONE, timeout=0.1)
                return
            except queue.Full:
                if self._errors:
                    try:
                        q.get_nowait()           # make room ourselves
                    except queue.Empty:
                        pass

    def _drain(self) -> None:
        for _ in self._comp_threads:
            self._post_done(self._comp_q)
        for t in self._comp_threads:
            t.join()
        for q in self._stripe_qs:
            self._post_done(q)
        for t in self._stripe_threads:
            t.join()

    def close(self) -> Dict[str, Any]:
        assert not self._closed
        self._drain()
        if self._errors:
            self._abort_files()
            raise self._errors[0]
        for rec in self._entries.values():
            if any(c is None for c in rec["chunks"]):   # pragma: no cover
                self._abort_files()
                raise IOError(f"{self.base}: pipeline lost a chunk")
        footer0 = {"format": 2, "stripes": self.stripes,
                   "chunk_bytes": self.chunk_bytes,
                   "entries": self._entries}
        for k, f in enumerate(self._files):
            idx = msgpack.packb(
                footer0 if k == 0 else {"format": 2, "stripe": k},
                use_bin_type=True)
            idx_off = f.tell()
            f.write(idx)
            f.seek(len(MAGIC2))
            f.write(struct.pack("<Q", idx_off))
            f.flush()
            os.fsync(f.fileno())
            f.close()
        # stripe 0 (holding the index) renamed last: readers only see a
        # complete stripe set once the index is durable
        for k in range(self.stripes - 1, -1, -1):
            p = stripe_path(self.base, k)
            os.rename(p + ".tmp", p)
        _remove_stale_layout(self.base, self.stripes)
        self._closed = True
        return self._entries

    def _abort_files(self) -> None:
        self._closed = True
        for f in self._files:
            try:
                f.close()
            except Exception:
                pass
        for k in range(self.stripes):
            try:
                os.remove(stripe_path(self.base, k) + ".tmp")
            except OSError:
                pass

    def abort(self) -> None:
        if self._closed:
            return
        self._errors.append(RuntimeError("aborted"))
        try:
            self._drain()
        finally:
            self._errors.clear()
            self._abort_files()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        if not self._closed:
            if exc[0] is None:
                self.close()
            else:                                    # failed write: no commit
                self.abort()


class PackReaderV2:
    """Chunked/striped pack reader with parallel chunk placement.

    Thread-safe: every thread gets its own file handle per stripe, so
    concurrent ``read_array`` calls (the restore thread pool) never
    contend on seek position.  When an `executor` is supplied, the chunks
    of one entry are read+CRC'd+decompressed in parallel, each landing
    directly in its slice of one preallocated buffer — no per-entry
    reassembly copies.
    """

    format = 2

    def __init__(self, base: str, verify: bool = True, executor=None):
        self.base = base
        # refs point at packs of other steps, relative to snapshots/
        self.root = os.path.dirname(os.path.dirname(os.path.abspath(base)))
        self._verify = verify
        self._executor = executor
        self._tls = threading.local()
        self._all_handles: List[Any] = []
        self._handles_lock = threading.Lock()
        self._stats = {"read_s": 0.0, "decompress_s": 0.0,
                       "read_bytes": 0.0}
        with open(stripe_path(base, 0), "rb") as f:
            magic = f.read(8)
            if magic != MAGIC2:
                raise ValueError(f"{base}.0: bad magic {magic!r}")
            (idx_off,) = struct.unpack("<Q", f.read(8))
            f.seek(idx_off)
            footer = msgpack.unpackb(f.read(), raw=False)
        self.index: Dict[str, Dict[str, Any]] = footer["entries"]
        self.stripes: int = footer["stripes"]
        self.chunk_bytes: int = footer["chunk_bytes"]
        self._priorities: Dict[str, int] = {}

    # ------------------------------------------------------------- layout
    def names(self):
        return list(self.index)

    def entry(self, name: str) -> Dict[str, Any]:
        return self.index[name]

    def entry_nbytes(self, name: str) -> int:
        """Raw (decoded) payload size of one entry."""
        return int(self.index[name]["raw_nbytes"])

    # ---------------------------------------------------------- schedule
    def set_priorities(self, order: List[str]) -> None:
        """Install a restore-priority schedule: `order` is the manifest's
        ``restore_order`` hint (entry names, most-critical first).  Names
        absent from the hint sort last, in index order."""
        self._priorities = {n: i for i, n in enumerate(order)}

    def entry_priority(self, name: str) -> int:
        return self._priorities.get(name, len(self._priorities)
                                    + 10_000_000)

    def schedule(self, names: Optional[List[str]] = None
                 ) -> List[Tuple[str, int, int]]:
        """(name, priority, raw_nbytes) for `names` (default: every
        entry), sorted by priority — the order the lazy materializer
        streams chunks in.  Stable for untagged names."""
        names = list(self.index) if names is None else names
        plan = [(n, self.entry_priority(n), self.entry_nbytes(n))
                for n in names]
        plan.sort(key=lambda t: t[1])
        return plan

    def _chunk_file(self, c: Dict[str, Any]) -> str:
        ref = c.get("ref")
        if ref:
            return stripe_path(os.path.join(self.root, ref), c["stripe"])
        return stripe_path(self.base, c["stripe"])

    def _handle(self, path: str):
        handles = getattr(self._tls, "handles", None)
        if handles is None:
            handles = self._tls.handles = {}
        f = handles.get(path)
        if f is None:
            f = handles[path] = open(path, "rb")
            with self._handles_lock:
                self._all_handles.append(f)
        return f

    # --------------------------------------------------------------- read
    def _read_chunk_into(self, name: str, c: Dict[str, Any],
                         out: np.ndarray, raw_off: int) -> None:
        path = self._chunk_file(c)
        t0 = time.perf_counter()
        try:
            f = self._handle(path)
        except FileNotFoundError:
            raise IOError(
                f"{self.base}:{name}: chunk file missing ({path}) — "
                f"referenced pack was deleted (broken incremental chain?)")
        f.seek(c["offset"])
        data = f.read(c["nbytes"])
        t1 = time.perf_counter()
        if len(data) != c["nbytes"]:
            raise IOError(
                f"{path}:{name}: chunk truncated at offset {c['offset']} "
                f"(got {len(data)} of {c['nbytes']} bytes)")
        if self._verify and crc32(data) != c["crc32"]:
            raise IOError(
                f"{path}:{name}: chunk CRC mismatch at offset "
                f"{c['offset']} (torn write?)")
        if c["codec"] != "raw":
            data = _decompress_blob(data, c["codec"])
        t2 = time.perf_counter()
        if len(data) != c["raw_nbytes"]:
            raise IOError(f"{path}:{name}: chunk decompressed to "
                          f"{len(data)} bytes, expected {c['raw_nbytes']}")
        out[raw_off:raw_off + len(data)] = np.frombuffer(data, np.uint8)
        with self._handles_lock:
            self._stats["read_s"] += t1 - t0
            self._stats["decompress_s"] += t2 - t1
            self._stats["read_bytes"] += c["nbytes"]

    def _read_raw(self, name: str) -> np.ndarray:
        rec = self.index[name]
        out = np.empty(rec["raw_nbytes"], np.uint8)
        offs = []
        pos = 0
        for c in rec["chunks"]:
            offs.append(pos)
            pos += c["raw_nbytes"]
        if pos != rec["raw_nbytes"]:
            raise IOError(f"{self.base}:{name}: chunk sizes sum to {pos}, "
                          f"index says {rec['raw_nbytes']}")
        if self._executor is not None and len(rec["chunks"]) > 1:
            futs = [self._executor.submit(self._read_chunk_into, name, c,
                                          out, o)
                    for c, o in zip(rec["chunks"], offs)]
            for fu in futs:
                fu.result()
        else:
            for c, o in zip(rec["chunks"], offs):
                self._read_chunk_into(name, c, out, o)
        return out

    def read_bytes(self, name: str) -> bytes:
        return self._read_raw(name).tobytes()

    def read_array(self, name: str) -> np.ndarray:
        rec = self.index[name]
        buf = self._read_raw(name)
        return buf.view(dtype_from_str(rec["dtype"])).reshape(rec["shape"])

    def read_stored_chunk(self, c: Dict[str, Any], verify: bool = True
                          ) -> bytes:
        """The *stored* (possibly compressed) bytes of one chunk record —
        the unit of cross-host transfer.  CRC-checked against the chunk's
        stored-byte hash so a torn stripe never ships."""
        path = self._chunk_file(c)
        f = self._handle(path)
        f.seek(c["offset"])
        data = f.read(c["nbytes"])
        if len(data) != c["nbytes"]:
            raise IOError(
                f"{path}: chunk truncated at offset {c['offset']} "
                f"(got {len(data)} of {c['nbytes']} bytes)")
        if verify and crc32(data) != c["crc32"]:
            raise IOError(f"{path}: chunk CRC mismatch at offset "
                          f"{c['offset']} (torn write?)")
        return data

    def own_chunks(self) -> List[Tuple[str, int, Dict[str, Any]]]:
        """(entry, chunk-index, record) for every chunk physically stored
        in THIS pack's stripes (``ref`` chunks live in a parent pack and
        are that pack's to export)."""
        out = []
        for name, rec in self.index.items():
            for j, c in enumerate(rec["chunks"]):
                if not c.get("ref"):
                    out.append((name, j, c))
        return out

    # ------------------------------------------------------------- verify
    def _verify_chunk(self, name: str, c: Dict[str, Any]) -> None:
        path = self._chunk_file(c)
        t0 = time.perf_counter()
        try:
            f = self._handle(path)
        except FileNotFoundError:
            raise IOError(
                f"{self.base}:{name}: chunk file missing ({path}) — "
                f"referenced pack was deleted (broken incremental chain?)")
        f.seek(c["offset"])
        data = f.read(c["nbytes"])
        t1 = time.perf_counter()
        if len(data) != c["nbytes"]:
            raise IOError(
                f"{path}:{name}: chunk truncated at offset {c['offset']} "
                f"(got {len(data)} of {c['nbytes']} bytes)")
        if crc32(data) != c["crc32"]:
            raise IOError(
                f"{path}:{name}: chunk CRC mismatch at offset "
                f"{c['offset']} (torn write?)")
        with self._handles_lock:
            self._stats["read_s"] += t1 - t0
            self._stats["read_bytes"] += c["nbytes"]

    def verify_entry(self, name: str) -> None:
        """Integrity-check one entry without decoding it.  Chunk CRCs
        cover the *stored* bytes, so verification never pays for
        decompression or buffer assembly — unlike v1, where verify must
        decode every entry the restore will decode again."""
        rec = self.index[name]
        chunks = rec["chunks"]
        if self._executor is not None and len(chunks) > 1:
            futs = [self._executor.submit(self._verify_chunk, name, c)
                    for c in chunks]
            for fu in futs:
                fu.result()
        else:
            for c in chunks:
                self._verify_chunk(name, c)

    def io_stats(self) -> Dict[str, float]:
        with self._handles_lock:
            return dict(self._stats)

    def close(self):
        with self._handles_lock:
            for f in self._all_handles:
                try:
                    f.close()
                except Exception:                      # pragma: no cover
                    pass
            self._all_handles.clear()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


AnyPackReader = Union[PackReader, PackReaderV2]


def open_pack(base: str, verify: bool = True,
              executor=None) -> AnyPackReader:
    """Open the pack at `base`, sniffing v1 (single file) vs v2 (stripe
    set).  v1 images written by older code read back byte-identically."""
    if os.path.exists(base):
        return PackReader(base, verify=verify)
    if os.path.exists(stripe_path(base, 0)):
        return PackReaderV2(base, verify=verify, executor=executor)
    raise FileNotFoundError(f"no pack at {base} (nor {base}.0)")


# ------------------------------------------------------------ v2 assembly
HEADER_BYTES = len(MAGIC2) + 8        # magic + index-offset placeholder


def write_pack_v2_from_chunks(base: str, footer: Dict[str, Any],
                              fetch) -> None:
    """Re-materialize a v2 pack from its logical index plus a chunk
    source — the receive side of a cross-host transfer.

    `footer` is the stripe-0 footer of the source pack (``entries`` with
    every chunk's stripe/offset/nbytes/crc32).  ``fetch(chunk_record)``
    must return that chunk's *stored* bytes.  Stripes are reconstructed
    byte-for-byte at the recorded offsets, so incremental children whose
    ``ref`` chunks point into this pack keep resolving, and every CRC in
    the index stays valid.  Commit order mirrors :class:`PackWriterV2`:
    all stripes written to ``*.tmp``, fsynced, stripe 0 (the index)
    renamed last.
    """
    stripes = footer["stripes"]
    per_stripe: List[List[Dict[str, Any]]] = [[] for _ in range(stripes)]
    for rec in footer["entries"].values():
        for c in rec["chunks"]:
            if not c.get("ref"):
                per_stripe[c["stripe"]].append(c)
    files = []
    try:
        for k in range(stripes):
            f = open(stripe_path(base, k) + ".tmp", "wb")
            files.append(f)
            f.write(MAGIC2)
            f.write(struct.pack("<Q", 0))
            pos = HEADER_BYTES
            for c in sorted(per_stripe[k], key=lambda c: c["offset"]):
                if c["offset"] != pos:
                    raise IOError(
                        f"{base}.{k}: non-contiguous chunk layout "
                        f"(offset {c['offset']}, expected {pos}) — "
                        f"source index is corrupt")
                data = fetch(c)
                if len(data) != c["nbytes"] or crc32(data) != c["crc32"]:
                    raise IOError(
                        f"{base}.{k}: fetched chunk does not match the "
                        f"index at offset {c['offset']} (corrupt source "
                        f"or chunk store)")
                f.write(data)
                pos += c["nbytes"]
            idx = msgpack.packb(
                footer if k == 0 else {"format": 2, "stripe": k},
                use_bin_type=True)
            f.write(idx)
            f.seek(len(MAGIC2))
            f.write(struct.pack("<Q", pos))
            f.flush()
            os.fsync(f.fileno())
            f.close()
    except BaseException:
        for f in files:
            try:
                f.close()
            except Exception:
                pass
        for k in range(stripes):
            try:
                os.remove(stripe_path(base, k) + ".tmp")
            except OSError:
                pass
        raise
    for k in range(stripes - 1, -1, -1):
        p = stripe_path(base, k)
        os.rename(p + ".tmp", p)
    _remove_stale_layout(base, stripes)
