"""Integrity + atomic-commit primitives for snapshot files.

A snapshot is only valid once its MANIFEST.json exists; the manifest is
written to a temp file and ``os.rename``d into place (atomic on POSIX), so a
crash mid-checkpoint can never leave a manifest pointing at torn data —
the restore path simply falls back to the previous committed snapshot.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import Any, Dict


def crc32(data: bytes, value: int = 0) -> int:
    return zlib.crc32(data, value) & 0xFFFFFFFF


def file_crc32(path: str, bufsize: int = 1 << 20) -> int:
    c = 0
    with open(path, "rb") as f:
        while True:
            b = f.read(bufsize)
            if not b:
                break
            c = crc32(b, c)
    return c


def atomic_write_bytes(path: str, data: bytes) -> None:
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    os.rename(tmp, path)


def atomic_write_json(path: str, obj: Dict[str, Any]) -> None:
    atomic_write_bytes(path, json.dumps(obj, indent=1, sort_keys=True
                                        ).encode())


def read_json(path: str) -> Dict[str, Any]:
    with open(path, "r") as f:
        return json.load(f)
