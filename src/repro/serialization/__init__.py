from repro.serialization.pack import (PackWriter, PackReader,  # noqa: F401
                                      PackWriterV2, PackReaderV2, open_pack,
                                      pack_files)
from repro.serialization.integrity import atomic_write_json, read_json, crc32  # noqa: F401
